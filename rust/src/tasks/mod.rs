//! Differentiable training tasks implemented natively in rust.
//!
//! These are the fast, deterministic substrates behind the Figure 2–4
//! sweeps (the paper's CIFAR-10/ViT study is substituted with a synthetic
//! vision task — see DESIGN.md "Environment-forced substitutions"). The
//! PJRT/JAX transformer path (`crate::lm`) covers the large-scale
//! Table 3/4 analogues; these tasks cover the optimizer-dynamics studies
//! where thousands of training runs are needed.

pub mod data;
pub mod linreg;
pub mod mlp;
pub mod quadratic;

use crate::util::Rng;

/// Evaluation result on the task's held-out set.
#[derive(Clone, Copy, Debug, Default)]
pub struct Eval {
    pub loss: f64,
    /// classification accuracy where applicable
    pub accuracy: Option<f64>,
}

/// A stochastic-gradient task: the paper's `f(x; ξ)` oracle.
///
/// The trait itself carries no `Send + Sync` bound so exotic backends
/// can stay single-threaded, but every in-repo task — including
/// [`crate::lm::LmTask`], now that the runtime's backends are
/// `Send + Sync` — satisfies both; the threaded runner takes
/// `dyn GradTask + Send + Sync` explicitly.
pub trait GradTask {
    fn name(&self) -> String;

    /// Number of flat parameters d.
    fn dim(&self) -> usize;

    /// Draw initial parameters.
    fn init_params(&self, rng: &mut Rng) -> Vec<f32>;

    /// Sample a minibatch with `rng` (the worker's private stream — the
    /// paper's ξ_{i,t}), write ∇f(x; ξ) into `grad`, return the batch loss.
    fn minibatch_grad(&self, params: &[f32], rng: &mut Rng, batch: usize, grad: &mut [f32])
        -> f32;

    /// Worker-aware variant for non-i.i.d. sharding (paper footnote 3:
    /// the method "should be directly applicable to non-i.i.d data").
    /// Default: ignore worker identity (i.i.d.). Tasks with data
    /// partitioning override this; the cluster always calls it.
    fn minibatch_grad_worker(
        &self,
        params: &[f32],
        rng: &mut Rng,
        batch: usize,
        grad: &mut [f32],
        _worker: usize,
        _nworkers: usize,
    ) -> f32 {
        self.minibatch_grad(params, rng, batch, grad)
    }

    /// Deterministic held-out evaluation.
    fn evaluate(&self, params: &[f32]) -> Eval;
}

#[cfg(test)]
pub(crate) fn finite_diff_check(
    task: &dyn GradTask,
    seed: u64,
    batch: usize,
    probes: usize,
    tol: f32,
) {
    // Gradient check: compare analytic grad against central differences on
    // the SAME minibatch (replayed by reusing the rng seed).
    let mut rng = Rng::new(seed);
    let params = task.init_params(&mut rng);
    let d = task.dim();
    let mut grad = vec![0.0f32; d];
    task.minibatch_grad(&params, &mut Rng::new(seed + 1), batch, &mut grad);
    let mut probe_rng = Rng::new(seed + 2);
    let eps = 1e-3f32;
    for _ in 0..probes {
        let k = probe_rng.below(d);
        let mut pp = params.clone();
        pp[k] += eps;
        let mut scratch = vec![0.0f32; d];
        let lp = task.minibatch_grad(&pp, &mut Rng::new(seed + 1), batch, &mut scratch);
        pp[k] = params[k] - eps;
        let lm = task.minibatch_grad(&pp, &mut Rng::new(seed + 1), batch, &mut scratch);
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (fd - grad[k]).abs() <= tol * (1.0 + fd.abs().max(grad[k].abs())),
            "grad check failed at coord {k}: analytic={} fd={fd}",
            grad[k]
        );
    }
}
