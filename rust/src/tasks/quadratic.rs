//! Noisy quadratic task: f(x) = ½ (x−x*)ᵀ diag(a) (x−x*), stochastic
//! gradient = ∇f + N(0, σ²I). The optimum and curvature are known in
//! closed form, which makes this the substrate for the theory benches
//! (Phase I/II, Theorems 4.4/4.6–4.8) where we need exact values of
//! dist(x, F) and the KKT score.

use super::{Eval, GradTask};
use crate::util::Rng;

pub struct Quadratic {
    pub dim: usize,
    /// diagonal curvature (condition number = max/min)
    pub curvature: Vec<f32>,
    /// optimum x*
    pub optimum: Vec<f32>,
    /// gradient noise σ
    pub sigma: f32,
    /// initial radius (how far x0 is from x*)
    pub init_radius: f32,
}

impl Quadratic {
    /// Ill-conditioned instance: curvature log-spaced in [1/κ, 1].
    pub fn new(dim: usize, kappa: f32, sigma: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let curvature: Vec<f32> = (0..dim)
            .map(|i| {
                let t = i as f32 / (dim.max(2) - 1) as f32;
                (1.0 / kappa).powf(1.0 - t)
            })
            .collect();
        let mut optimum = vec![0.0f32; dim];
        rng.fill_normal(&mut optimum, 1.0);
        Quadratic { dim, curvature, optimum, sigma, init_radius: 5.0 }
    }

    /// True (noise-free) gradient at x.
    pub fn true_grad(&self, params: &[f32], out: &mut [f32]) {
        for ((o, (&a, &xs)), &x) in out
            .iter_mut()
            .zip(self.curvature.iter().zip(&self.optimum))
            .zip(params)
        {
            *o = a * (x - xs);
        }
    }

    /// True loss at x.
    pub fn true_loss(&self, params: &[f32]) -> f64 {
        params
            .iter()
            .zip(self.curvature.iter().zip(&self.optimum))
            .map(|(&x, (&a, &xs))| 0.5 * a as f64 * ((x - xs) as f64).powi(2))
            .sum()
    }
}

impl GradTask for Quadratic {
    fn name(&self) -> String {
        format!("quadratic-d{}", self.dim)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut p = vec![0.0f32; self.dim];
        rng.fill_normal(&mut p, self.init_radius);
        p
    }

    fn minibatch_grad(
        &self,
        params: &[f32],
        rng: &mut Rng,
        batch: usize,
        grad: &mut [f32],
    ) -> f32 {
        self.true_grad(params, grad);
        // batch of b i.i.d. noisy grads = true grad + N(0, σ²/b)
        let eff_sigma = self.sigma / (batch.max(1) as f32).sqrt();
        for g in grad.iter_mut() {
            *g += rng.normal_f32(0.0, eff_sigma);
        }
        self.true_loss(params) as f32
    }

    fn evaluate(&self, params: &[f32]) -> Eval {
        Eval { loss: self.true_loss(params), accuracy: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_zero_at_optimum() {
        let q = Quadratic::new(8, 10.0, 0.1, 1);
        assert!(q.true_loss(&q.optimum) < 1e-12);
    }

    #[test]
    fn gradient_points_away_from_optimum() {
        let q = Quadratic::new(4, 1.0, 0.0, 2);
        let x: Vec<f32> = q.optimum.iter().map(|&o| o + 1.0).collect();
        let mut g = vec![0.0; 4];
        q.true_grad(&x, &mut g);
        assert!(g.iter().all(|&gi| gi > 0.0));
    }

    #[test]
    fn noise_shrinks_with_batch() {
        let q = Quadratic::new(16, 1.0, 1.0, 3);
        let x = vec![0.0f32; 16];
        let mut g = vec![0.0f32; 16];
        let reps = 200;
        let mut var_b = |b: usize| -> f64 {
            let mut rng = Rng::new(99);
            let mut acc = 0.0;
            for _ in 0..reps {
                q.minibatch_grad(&x, &mut rng, b, &mut g);
                let mut tg = vec![0.0f32; 16];
                q.true_grad(&x, &mut tg);
                acc += g
                    .iter()
                    .zip(&tg)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>();
            }
            acc / reps as f64
        };
        let v1 = var_b(1);
        let v16 = var_b(16);
        assert!(v16 < v1 / 8.0, "v1={v1} v16={v16}");
    }

    #[test]
    fn finite_diff() {
        let q = Quadratic::new(12, 5.0, 0.0, 4);
        super::super::finite_diff_check(&q, 7, 4, 8, 2e-2);
    }
}
