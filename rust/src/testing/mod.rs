//! In-repo property-testing mini-framework.
//!
//! The vendored crate set has no `proptest`, so this module provides the
//! pieces we actually use: seeded generators, a `forall` driver that runs
//! a property over many random cases, and failure reporting that prints
//! the case index + seed so a failure replays deterministically:
//!
//! ```text
//! property failed at case 37 (seed 0xDEADBEEF): <debug of input>
//! ```
//!
//! Shrinking is deliberately simple: for `Vec`-shaped inputs we retry the
//! property on prefixes to report a smaller witness when possible.

use crate::util::Rng;
use std::fmt::Debug;

/// Default number of cases per property (overridable via DLION_PROPTEST_CASES).
pub fn default_cases() -> usize {
    std::env::var("DLION_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` on `cases` random inputs drawn by `gen`. Panics with a
/// replayable message on the first failure.
pub fn forall<T: Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed:#x}): input = {input:?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so it can
/// explain *why* it failed.
pub fn forall_explain<T: Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(why) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed:#x}): {why}\n  input = {input:?}"
            );
        }
    }
}

/// Vec-input variant with prefix shrinking: on failure, finds the shortest
/// failing prefix before panicking.
pub fn forall_vec<T: Clone + Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> Vec<T>,
    mut prop: impl FnMut(&[T]) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            // Prefix shrink: binary search the shortest failing prefix.
            let mut lo = 0usize; // prop passes on input[..lo] (empty passes or not, we check)
            let mut hi = input.len(); // prop fails on input[..hi]
            if prop(&input[..0]) {
                while lo + 1 < hi {
                    let mid = (lo + hi) / 2;
                    if prop(&input[..mid]) {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
            } else {
                hi = 0;
            }
            let witness = &input[..hi.max(1).min(input.len())];
            panic!(
                "property failed at case {case} (seed {seed:#x}); shrunk witness ({} of {} elems) = {witness:?}",
                witness.len(),
                input.len()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

/// Vector of f32 drawn from N(0, sigma^2), random length in [min_len, max_len].
pub fn gen_vec_normal(
    rng: &mut Rng,
    min_len: usize,
    max_len: usize,
    sigma: f32,
) -> Vec<f32> {
    let len = min_len + rng.below(max_len - min_len + 1);
    let mut v = vec![0.0; len];
    rng.fill_normal(&mut v, sigma);
    v
}

/// Vector of signs in {-1, 0, +1} as i8 with a given zero probability.
pub fn gen_vec_tern(rng: &mut Rng, min_len: usize, max_len: usize, p_zero: f64) -> Vec<i8> {
    let len = min_len + rng.below(max_len - min_len + 1);
    (0..len)
        .map(|_| {
            if rng.uniform() < p_zero {
                0
            } else if rng.next_u64() & 1 == 0 {
                1
            } else {
                -1
            }
        })
        .collect()
}

/// Vector of strict signs in {-1, +1} as i8.
pub fn gen_vec_sign(rng: &mut Rng, min_len: usize, max_len: usize) -> Vec<i8> {
    let len = min_len + rng.below(max_len - min_len + 1);
    (0..len).map(|_| if rng.next_u64() & 1 == 0 { 1i8 } else { -1i8 }).collect()
}

/// Assert two float slices are elementwise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "{ctx}: mismatch at [{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially_true() {
        forall(1, 32, |r| r.next_u64(), |_| true);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(2, 32, |r| r.below(10), |&x| x < 5);
    }

    #[test]
    #[should_panic(expected = "shrunk witness")]
    fn forall_vec_shrinks() {
        forall_vec(
            3,
            16,
            |r| gen_vec_normal(r, 8, 32, 1.0),
            |xs| xs.iter().all(|&x| x.abs() < 0.5), // will fail fast
        );
    }

    #[test]
    fn generators_respect_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..100 {
            let v = gen_vec_normal(&mut r, 3, 7, 1.0);
            assert!((3..=7).contains(&v.len()));
            let t = gen_vec_tern(&mut r, 0, 5, 0.3);
            assert!(t.len() <= 5);
            assert!(t.iter().all(|&x| (-1..=1).contains(&x)));
            let s = gen_vec_sign(&mut r, 1, 4);
            assert!(s.iter().all(|&x| x == 1 || x == -1));
        }
    }

    #[test]
    fn allclose_accepts_close() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0 - 1e-7], 1e-5, 1e-5, "t");
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[1.1], 1e-5, 1e-5, "t");
    }
}
