//! Theory diagnostics from Section 4 / Appendix A.
//!
//! * Feasible set F = {x : ‖λx‖∞ ≤ 1} and dist(x, F) (Theorem 4.4 /
//!   Phase I).
//! * KKT surrogate score S(x) = ⟨∇f, sign(∇f) + λx⟩ (eq. 9 / Phase II).
//! * Phase detector + trace recorder used by the `constraint_dynamics`
//!   example.

use crate::util::math::sign;

/// Elementwise distance to the box F = {x : |λ x_k| ≤ 1}; returns the
/// vector of per-coordinate violations max(|λx|−1, 0)/λ.
pub fn box_violation(x: &[f32], lambda: f32) -> Vec<f32> {
    x.iter()
        .map(|&xi| {
            let v = (lambda * xi).abs() - 1.0;
            if v > 0.0 {
                v / lambda
            } else {
                0.0
            }
        })
        .collect()
}

/// dist(x, F) in the L2 norm (Theorem 4.4 holds for any norm; L2 is what
/// we plot).
pub fn dist_to_feasible(x: &[f32], lambda: f32) -> f64 {
    crate::util::math::l2_norm(&box_violation(x, lambda))
}

/// dist(x, F) in the L∞ norm.
pub fn dist_to_feasible_linf(x: &[f32], lambda: f32) -> f64 {
    crate::util::math::linf_norm(&box_violation(x, lambda))
}

/// Is x inside F?
pub fn in_feasible(x: &[f32], lambda: f32) -> bool {
    x.iter().all(|&xi| (lambda * xi).abs() <= 1.0 + 1e-6)
}

/// KKT surrogate score S(x) = ⟨∇f(x), sign(∇f(x)) + λx⟩ (paper eq. 9).
/// Inside F this is ≥ 0 and S(x)=0 at KKT points (Proposition 4.5).
pub fn kkt_score(grad: &[f32], x: &[f32], lambda: f32) -> f64 {
    grad.iter()
        .zip(x)
        .map(|(&g, &xi)| g as f64 * (sign(g) as f64 + (lambda * xi) as f64))
        .sum()
}

/// Phase of the Lion dynamics at x (Section 4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// outside F: constraint-enforcing (exponential approach)
    ConstraintEnforcing,
    /// inside F: objective-minimizing
    Optimizing,
}

pub fn phase(x: &[f32], lambda: f32) -> Phase {
    if in_feasible(x, lambda) {
        Phase::Optimizing
    } else {
        Phase::ConstraintEnforcing
    }
}

/// Verify the Phase-I contraction bound on a recorded distance trace:
/// dist_t ≤ (1−ελ)^(t−s)·dist_s for all s ≤ t (up to `slack` multiplier,
/// which absorbs the ±ε·Δ drift inside the bound's derivation).
pub fn check_phase1_contraction(dists: &[f64], eps_lambda: f64, slack: f64) -> Result<(), String> {
    let rate = 1.0 - eps_lambda;
    for s in 0..dists.len() {
        for t in s..dists.len() {
            let bound = rate.powi((t - s) as i32) * dists[s] * slack + 1e-9;
            if dists[t] > bound && dists[t] > 1e-6 {
                return Err(format!(
                    "contraction violated: dist[{t}]={} > (1-ελ)^{}·dist[{s}]={bound}",
                    dists[t],
                    t - s
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::lion::Lion;
    use crate::optim::{LionParams, Optimizer};
    use crate::tasks::quadratic::Quadratic;
    use crate::tasks::GradTask;
    use crate::util::Rng;

    #[test]
    fn violation_zero_inside_box() {
        let lambda = 0.5;
        let x = vec![1.9, -1.9, 0.0];
        assert_eq!(dist_to_feasible(&x, lambda), 0.0);
        assert!(in_feasible(&x, lambda));
    }

    #[test]
    fn violation_positive_outside() {
        let lambda = 1.0;
        let x = vec![2.0, 0.0];
        assert!((dist_to_feasible(&x, lambda) - 1.0).abs() < 1e-9);
        assert_eq!(phase(&x, lambda), Phase::ConstraintEnforcing);
    }

    #[test]
    fn kkt_score_nonnegative_inside_box() {
        // Proposition A.5's intermediate fact: S_k(x) ≥ 0 when ‖λx‖∞ ≤ 1.
        let mut rng = Rng::new(0x200);
        let lambda = 0.7;
        for _ in 0..200 {
            let d = 16;
            let mut g = vec![0.0f32; d];
            rng.fill_normal(&mut g, 1.0);
            let x: Vec<f32> =
                (0..d).map(|_| rng.uniform_in(-1.0, 1.0) / lambda).collect();
            assert!(kkt_score(&g, &x, lambda) >= -1e-6);
        }
    }

    #[test]
    fn kkt_score_zero_at_boundary_kkt_point() {
        // Case II of Prop 4.5: x_k = −sign(∂f)/λ zeroes S_k.
        let lambda = 2.0;
        let g = vec![3.0f32, -1.5];
        let x = vec![-1.0 / lambda, 1.0 / lambda];
        assert!(kkt_score(&g, &x, lambda).abs() < 1e-9);
    }

    #[test]
    fn lion_phase1_contracts_at_paper_rate() {
        // Theorem 4.4: dist(x_t, F) ≤ (1−ελ)^{t−s} dist(x_s, F).
        let lambda = 0.5f32;
        let eps = 0.05f32;
        let d = 32;
        let q = Quadratic::new(d, 3.0, 0.0, 0x201);
        let mut lion = Lion::new(d, LionParams { beta1: 0.9, beta2: 0.99, weight_decay: lambda });
        let mut x = vec![20.0f32; d]; // far outside F (|λx| = 10)
        let mut g = vec![0.0f32; d];
        let mut dists = Vec::new();
        for _ in 0..200 {
            dists.push(dist_to_feasible(&x, lambda));
            q.minibatch_grad(&x, &mut Rng::new(1), 8, &mut g);
            lion.step(&mut x, &g, eps);
        }
        // slack 1.05 absorbs the ±ε drift of the binary update term
        check_phase1_contraction(&dists, (eps * lambda) as f64, 1.05).unwrap();
        // and the iterate ends inside F
        assert!(in_feasible(&x, lambda + 1e-4));
    }

    #[test]
    fn contraction_checker_rejects_flat_traces() {
        let dists = vec![10.0, 10.0, 10.0, 10.0];
        assert!(check_phase1_contraction(&dists, 0.1, 1.0).is_err());
    }
}
