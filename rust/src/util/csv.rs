//! Tiny CSV writer used by the metrics logger and bench harness.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    ncols: usize,
}

impl CsvWriter {
    /// Create (truncate) `path` and write the header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, ncols: header.len() })
    }

    /// Write one row of string-formatted cells.
    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(cells.len(), self.ncols, "csv row width mismatch");
        writeln!(self.out, "{}", cells.join(","))
    }

    /// Write a row of f64 values with `{:.6}` formatting.
    pub fn row_f64(&mut self, cells: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = cells.iter().map(|x| format!("{x:.6}")).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Format helper: a `cells![a, b, c]`-like builder for mixed types.
#[macro_export]
macro_rules! csv_cells {
    ($($x:expr),* $(,)?) => {
        vec![$(format!("{}", $x)),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join(format!("dlion_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&csv_cells!["x", 1]).unwrap();
            w.row_f64(&[1.5, 2.5]).unwrap();
            w.flush().unwrap();
        }
        let content = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = content.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "x,1");
        assert_eq!(lines[2], "1.500000,2.500000");
        std::fs::remove_dir_all(&dir).ok();
    }
}
