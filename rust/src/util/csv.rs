//! Tiny CSV writer used by the metrics logger and bench harness.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    ncols: usize,
}

impl CsvWriter {
    /// Create (truncate) `path` and write the header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, ncols: header.len() })
    }

    /// Write one row of string-formatted cells. Cells containing a
    /// comma, quote, or newline are quoted per RFC 4180 (composite
    /// strategy names like `bandwidth-aware(a,b)` carry commas).
    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(cells.len(), self.ncols, "csv row width mismatch");
        let quoted: Vec<String> = cells.iter().map(|c| quote_cell(c)).collect();
        writeln!(self.out, "{}", quoted.join(","))
    }

    /// Write a row of f64 values with `{:.6}` formatting.
    pub fn row_f64(&mut self, cells: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = cells.iter().map(|x| format!("{x:.6}")).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// RFC 4180 quoting: wrap in quotes (doubling embedded quotes) only when
/// the cell contains a comma, quote, or line break.
fn quote_cell(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') || cell.contains('\r') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Format helper: a `cells![a, b, c]`-like builder for mixed types.
#[macro_export]
macro_rules! csv_cells {
    ($($x:expr),* $(,)?) => {
        vec![$(format!("{}", $x)),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join(format!("dlion_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&csv_cells!["x", 1]).unwrap();
            w.row_f64(&[1.5, 2.5]).unwrap();
            w.flush().unwrap();
        }
        let content = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = content.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "x,1");
        assert_eq!(lines[2], "1.500000,2.500000");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cells_with_commas_are_quoted() {
        let dir = std::env::temp_dir().join(format!("dlion_csvq_{}", std::process::id()));
        let path = dir.join("q.csv");
        {
            let mut w = CsvWriter::create(&path, &["strategy", "n"]).unwrap();
            w.row(&csv_cells!["bandwidth-aware(d-lion-mavo,g-lion)", 4]).unwrap();
            w.row(&csv_cells!["say \"hi\"", 1]).unwrap();
            w.flush().unwrap();
        }
        let content = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = content.lines().collect();
        assert_eq!(lines[1], "\"bandwidth-aware(d-lion-mavo,g-lion)\",4");
        assert_eq!(lines[2], "\"say \"\"hi\"\"\",1");
        // every row still has exactly one unquoted separator
        for l in &lines[1..] {
            let mut in_q = false;
            let seps = l.chars().filter(|&c| {
                if c == '"' {
                    in_q = !in_q;
                }
                c == ',' && !in_q
            });
            assert_eq!(seps.count(), 1, "row {l}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
