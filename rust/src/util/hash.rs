//! Content hashing for the artifact pipeline (no `sha2`/`blake3` in the
//! offline vendored crate set — see DESIGN.md "Environment-forced
//! substitutions").
//!
//! [`fnv64`] is FNV-1a over bytes, used two ways by `runtime`:
//! * **payload checksums** — `manifest.json` records the hash of every
//!   artifact file so `Runtime::load` can refuse stale or truncated
//!   payloads by name instead of executing them;
//! * **`source_hash`** — `dlion gen-artifacts` hashes the generation
//!   inputs (model config + seed + format version) so an unchanged
//!   source is a no-op rebuild (the casettek/raster recompilation-cache
//!   design).
//!
//! FNV-1a is not cryptographic; it guards against corruption and stale
//! caches, not adversaries — the same trust model as a build cache.

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64-bit hasher.
#[derive(Clone, Debug)]
pub struct Fnv64 {
    state: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64 { state: FNV_OFFSET }
    }

    /// Absorb bytes.
    pub fn update(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Current digest.
    pub fn digest(&self) -> u64 {
        self.state
    }

    /// Current digest as the fixed-width hex string stored in manifests.
    pub fn hex(&self) -> String {
        format!("{:016x}", self.state)
    }
}

/// One-shot FNV-1a 64 over a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.digest()
}

/// One-shot hex digest (the manifest checksum format).
pub fn fnv64_hex(bytes: &[u8]) -> String {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.hex()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // FNV-1a reference vectors (Noll's test suite).
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data = b"the lion signs the momentum";
        let mut h = Fnv64::new();
        h.update(&data[..7]).update(&data[7..]);
        assert_eq!(h.digest(), fnv64(data));
        assert_eq!(h.hex(), fnv64_hex(data));
        assert_eq!(h.hex().len(), 16);
    }

    #[test]
    fn sensitive_to_every_byte() {
        let a = fnv64(b"params_init.bin v1");
        let b = fnv64(b"params_init.bin v2");
        assert_ne!(a, b);
    }
}
