//! Minimal JSON parser + emitter.
//!
//! The AOT pipeline (`python/compile/aot.py`) writes a `manifest.json`
//! describing artifact shapes and parameter layouts; the vendored crate
//! set has no `serde_json`, so this module implements the subset of JSON
//! we need: objects, arrays, strings (with escapes), numbers, booleans,
//! and null. It is a strict recursive-descent parser with byte offsets
//! in error messages.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` convenience (None if not an object / key absent).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, message: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or '}'");
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or ']'");
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(JsonError {
                                offset: self.pos,
                                message: "truncated \\u escape".into(),
                            })?;
                            let d = (c as char)
                                .to_digit(16)
                                .ok_or(JsonError {
                                    offset: self.pos,
                                    message: "bad hex digit".into(),
                                })?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // UTF-8 continuation: copy raw bytes of the multibyte char.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.bytes.len());
                    self.pos = end;
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..end]).unwrap_or("\u{FFFD}"),
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Serialize a JSON value (compact).
pub fn emit(v: &Json) -> String {
    let mut s = String::new();
    emit_into(v, &mut s);
    s
}

fn emit_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit_into(&Json::Str(k.clone()), out);
                out.push(':');
                emit_into(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"flag":true,"n":null,"nested":{"k":-3}}"#;
        let v = parse(src).unwrap();
        let emitted = emit(&v);
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ∆\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∆"));
    }
}
