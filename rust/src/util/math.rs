//! Small numeric helpers shared across the crate.

/// Elementwise sign with sign(0) = 0 (matches `jnp.sign` and the paper).
#[inline]
pub fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Integer sign of an i32 (-1, 0, 1).
#[inline]
pub fn isign(x: i32) -> i8 {
    match x.cmp(&0) {
        std::cmp::Ordering::Greater => 1,
        std::cmp::Ordering::Less => -1,
        std::cmp::Ordering::Equal => 0,
    }
}

/// L2 norm.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// L1 norm.
pub fn l1_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| x.abs() as f64).sum()
}

/// L-infinity norm.
pub fn linf_norm(xs: &[f32]) -> f64 {
    xs.iter().fold(0.0f64, |acc, &x| acc.max(x.abs() as f64))
}

/// Dot product in f64 accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// axpy: y += alpha * x.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Scale in place: x *= alpha.
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile in [0, 100] via nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Numerically stable log-sum-exp over a slice.
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// Softmax into `out` (stable).
pub fn softmax(xs: &[f32], out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    let lse = log_sum_exp(xs);
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = (x - lse).exp();
    }
}

/// Ceil of integer log2(n+1): bits to represent integers 0..=n.
pub fn bits_for_count(n: usize) -> u32 {
    usize::BITS - n.leading_zeros()
}

/// Cosine learning-rate schedule with linear warmup, as used by the paper's
/// CIFAR-10 experiments ("cosine learning rate scheduler").
pub fn cosine_lr(step: usize, total: usize, warmup: usize, base: f64, min_frac: f64) -> f64 {
    if total == 0 {
        return base;
    }
    if step < warmup {
        return base * (step + 1) as f64 / warmup.max(1) as f64;
    }
    let t = (step - warmup) as f64 / (total.saturating_sub(warmup)).max(1) as f64;
    let cos = 0.5 * (1.0 + (std::f64::consts::PI * t.min(1.0)).cos());
    base * (min_frac + (1.0 - min_frac) * cos)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_matches_paper_convention() {
        assert_eq!(sign(2.5), 1.0);
        assert_eq!(sign(-0.1), -1.0);
        assert_eq!(sign(0.0), 0.0);
        assert_eq!(sign(-0.0), 0.0);
    }

    #[test]
    fn isign_basic() {
        assert_eq!(isign(5), 1);
        assert_eq!(isign(-5), -1);
        assert_eq!(isign(0), 0);
    }

    #[test]
    fn norms() {
        let v = [3.0, -4.0];
        assert!((l2_norm(&v) - 5.0).abs() < 1e-12);
        assert!((l1_norm(&v) - 7.0).abs() < 1e-12);
        assert!((linf_norm(&v) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn dot_and_axpy() {
        let a = [1.0, 2.0, 3.0];
        let mut b = [4.0, 5.0, 6.0];
        assert!((dot(&a, &b) - 32.0).abs() < 1e-12);
        axpy(2.0, &a, &mut b);
        assert_eq!(b, [6.0, 9.0, 12.0]);
    }

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((mean(&xs) - 3.0).abs() < 1e-12);
        assert!((median(&xs) - 3.0).abs() < 1e-12);
        assert!((median(&[1.0, 2.0, 3.0, 4.0]) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0f64.sqrt()).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn softmax_sums_to_one() {
        let xs = [1.0, 2.0, 3.0, -100.0];
        let mut out = [0.0; 4];
        softmax(&xs, &mut out);
        let s: f32 = out.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(out[2] > out[1] && out[1] > out[0]);
    }

    #[test]
    fn log_sum_exp_stable_for_large() {
        let xs = [1000.0, 1000.0];
        let lse = log_sum_exp(&xs);
        assert!((lse - (1000.0 + 2f32.ln())).abs() < 1e-3);
    }

    #[test]
    fn bits_for_count_matches_table1() {
        // Averaging downlink needs ceil(log2(N+1)) bits per element.
        assert_eq!(bits_for_count(1), 1);
        assert_eq!(bits_for_count(2), 2);
        assert_eq!(bits_for_count(4), 3);
        assert_eq!(bits_for_count(8), 4);
        assert_eq!(bits_for_count(32), 6);
    }

    #[test]
    fn cosine_lr_schedule() {
        let base = 1.0;
        // warmup ramps up
        assert!(cosine_lr(0, 100, 10, base, 0.0) < cosine_lr(9, 100, 10, base, 0.0));
        // decays to ~0 at the end
        assert!(cosine_lr(99, 100, 10, base, 0.0) < 0.01);
        // peak right after warmup
        assert!((cosine_lr(10, 100, 10, base, 0.0) - base).abs() < 1e-9);
    }
}
