//! Shared utilities: deterministic RNG, mini-JSON, math/stats, CSV.
//!
//! These exist because the offline build environment vendors only the
//! `xla` crate's dependency closure — no `rand`, `serde`, or `csv`
//! crates — so the substrates are implemented in-repo (see DESIGN.md
//! "Environment-forced substitutions").

pub mod csv;
pub mod hash;
pub mod json;
pub mod math;
pub mod parallel;
pub mod rng;

pub use rng::Rng;
