//! Deterministic scoped-thread parallelism for the chunked round hot
//! path (no external thread-pool crates in the offline build).
//!
//! The helpers split index-aligned slices into contiguous per-thread
//! blocks and run a pure-per-item closure on each block; results are
//! collected back in index order, so the output is bit-identical to the
//! sequential loop regardless of scheduling. Callers gate on
//! [`auto_threads`] so small models (the sweep benches run thousands of
//! tiny rounds) never pay thread-spawn overhead.

/// Work sizes below this many elements run single-threaded: at ~1 ns per
/// element, spawn/join overhead would dominate the round.
pub const PAR_MIN_ELEMS: usize = 1 << 16;

/// Thread count for a hot-path operation over `elems` elements: 1 below
/// [`PAR_MIN_ELEMS`], otherwise the machine's available parallelism.
pub fn auto_threads(elems: usize) -> usize {
    if elems < PAR_MIN_ELEMS {
        1
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Map `f` over the zipped slices in parallel, returning the results in
/// index order. `f(&mut a[i], &b[i], i)` must be pure per index (no
/// cross-item dependence) for the output to be deterministic.
///
/// Each thread writes its block of results straight into one
/// preallocated output buffer (`MaybeUninit` slots), so a parallel
/// round pays zero extra allocation or copy over the sequential loop —
/// the old per-thread `Vec<Vec<T>>` + flatten is gone.
pub fn par_zip_map<A, B, T, F>(a: &mut [A], b: &[B], nthreads: usize, f: F) -> Vec<T>
where
    A: Send,
    B: Sync,
    T: Send,
    F: Fn(&mut A, &B, usize) -> T + Sync,
{
    use std::mem::MaybeUninit;

    let n = a.len();
    assert_eq!(n, b.len(), "par_zip_map slices must be index-aligned");
    let nthreads = nthreads.min(n).max(1);
    if nthreads <= 1 {
        return a.iter_mut().zip(b).enumerate().map(|(i, (x, y))| f(x, y, i)).collect();
    }
    let block = n.div_ceil(nthreads);
    let mut out: Vec<MaybeUninit<T>> = Vec::with_capacity(n);
    // SAFETY: MaybeUninit slots are valid uninitialized; length == capacity.
    unsafe { out.set_len(n) };
    std::thread::scope(|s| {
        for (bi, ((ac, bc), oc)) in
            a.chunks_mut(block).zip(b.chunks(block)).zip(out.chunks_mut(block)).enumerate()
        {
            let f = &f;
            s.spawn(move || {
                for (j, ((x, y), o)) in ac.iter_mut().zip(bc).zip(oc.iter_mut()).enumerate() {
                    o.write(f(x, y, bi * block + j));
                }
            });
        }
    });
    // The blocks tile 0..n exactly, and the scope joined every thread, so
    // each slot was written once. (If a closure panicked, the scope
    // re-panics above and the MaybeUninit vec drops without running T
    // destructors — written results leak, but no uninitialized read.)
    let mut out = std::mem::ManuallyDrop::new(out);
    // SAFETY: all n elements initialized; layout of MaybeUninit<T> == T.
    unsafe { Vec::from_raw_parts(out.as_mut_ptr() as *mut T, out.len(), out.capacity()) }
}

/// Run `f` over every item in parallel, mutating in place (the round
/// engine's (worker × chunk) encode jobs: each item owns disjoint
/// `&mut` state and output slices, so blocks never alias). Items are
/// processed in contiguous index blocks; `f(&mut items[i], i)` must be
/// pure per index for determinism.
pub fn par_for_each_mut<T, F>(items: &mut [T], nthreads: usize, f: F)
where
    T: Send,
    F: Fn(&mut T, usize) + Sync,
{
    let n = items.len();
    let nthreads = nthreads.min(n).max(1);
    if nthreads <= 1 {
        for (i, it) in items.iter_mut().enumerate() {
            f(it, i);
        }
        return;
    }
    let block = n.div_ceil(nthreads);
    std::thread::scope(|s| {
        for (bi, blk) in items.chunks_mut(block).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, it) in blk.iter_mut().enumerate() {
                    f(it, bi * block + j);
                }
            });
        }
    });
}

/// Run `f` over two mutably zipped slices in parallel (e.g. each
/// worker's logic applying the broadcast to its own replica).
pub fn par_zip2_mut<A, B, F>(a: &mut [A], b: &mut [B], nthreads: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(&mut A, &mut B, usize) + Sync,
{
    let n = a.len();
    assert_eq!(n, b.len(), "par_zip2_mut slices must be index-aligned");
    let nthreads = nthreads.min(n).max(1);
    if nthreads <= 1 {
        for (i, (x, y)) in a.iter_mut().zip(b.iter_mut()).enumerate() {
            f(x, y, i);
        }
        return;
    }
    let block = n.div_ceil(nthreads);
    std::thread::scope(|s| {
        for (bi, (ac, bc)) in a.chunks_mut(block).zip(b.chunks_mut(block)).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, (x, y)) in ac.iter_mut().zip(bc.iter_mut()).enumerate() {
                    f(x, y, bi * block + j);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_sequential_for_any_thread_count() {
        let b: Vec<usize> = (0..37).collect();
        let expect: Vec<usize> = (0..37).map(|i| i * 3 + i).collect();
        for t in [1usize, 2, 3, 8, 64] {
            let mut a: Vec<usize> = (0..37).map(|i| i * 3).collect();
            let got = par_zip_map(&mut a, &b, t, |x, y, i| {
                *x += y;
                assert_eq!(*y, i, "index alignment");
                *x
            });
            assert_eq!(got, expect, "nthreads={t}");
        }
    }

    #[test]
    fn par_zip2_mutates_both_sides() {
        let mut a = vec![1i64; 10];
        let mut b: Vec<i64> = (0..10).collect();
        par_zip2_mut(&mut a, &mut b, 4, |x, y, i| {
            *x += *y;
            *y = i as i64 * 10;
        });
        assert_eq!(a, (0..10).map(|i| 1 + i).collect::<Vec<i64>>());
        assert_eq!(b, (0..10).map(|i| i * 10).collect::<Vec<i64>>());
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut a: Vec<u8> = Vec::new();
        let b: Vec<u8> = Vec::new();
        let got: Vec<u8> = par_zip_map(&mut a, &b, 8, |x, _, _| *x);
        assert!(got.is_empty());
        let mut a = vec![5u8];
        let got = par_zip_map(&mut a, &[2u8], 8, |x, y, _| *x + *y);
        assert_eq!(got, vec![7]);
    }

    #[test]
    fn par_map_handles_nonclone_results_and_uneven_blocks() {
        // String results exercise the MaybeUninit path with a Drop type;
        // 37 items across 8 threads leaves a short trailing block.
        let b: Vec<usize> = (0..37).collect();
        let mut a: Vec<usize> = (0..37).collect();
        let got = par_zip_map(&mut a, &b, 8, |x, y, i| format!("{}:{}", *x + *y, i));
        let expect: Vec<String> = (0..37).map(|i| format!("{}:{}", 2 * i, i)).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn par_for_each_matches_sequential_for_any_thread_count() {
        for t in [1usize, 2, 3, 8, 64] {
            let mut items: Vec<(usize, usize)> = (0..29).map(|i| (i, 0)).collect();
            par_for_each_mut(&mut items, t, |it, i| {
                assert_eq!(it.0, i, "index alignment");
                it.1 = it.0 * 7;
            });
            assert!(items.iter().all(|&(i, v)| v == i * 7), "nthreads={t}");
        }
        let mut empty: Vec<u8> = Vec::new();
        par_for_each_mut(&mut empty, 4, |_, _| unreachable!());
    }

    #[test]
    fn auto_threads_gates_small_work() {
        assert_eq!(auto_threads(10), 1);
        assert!(auto_threads(PAR_MIN_ELEMS) >= 1);
    }
}
