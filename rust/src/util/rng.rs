//! Deterministic pseudo-random number generation.
//!
//! The offline vendored crate set has no `rand`, so we implement the
//! well-known splitmix64 (seeding) + xoshiro256** (stream) generators.
//! Both are public-domain reference algorithms (Blackman & Vigna).
//! Everything in this repo that needs randomness goes through [`Rng`]
//! so experiments are reproducible from a single `u64` seed.

/// splitmix64 step — used to expand a single u64 seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Fast, high quality, tiny state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-cryptographic) purposes.
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// N(mu, sigma^2) as f32.
    #[inline]
    pub fn normal_f32(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal() as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, sigma);
        }
    }

    /// Fill a slice with uniform [lo, hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform_in(lo, hi);
        }
    }

    /// Sample a random sign vector (+1/-1) as f32.
    pub fn fill_signs(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(29);
        let idx = r.sample_indices(50, 10);
        assert_eq!(idx.len(), 10);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(idx.iter().all(|&i| i < 50));
    }
}
