//! Elastic quorum rounds under seeded fault injection: the kill /
//! delay / corrupt matrix over strategies × topologies × transports.
//!
//! What must hold (the chaos contract):
//! * every planned run **completes** — no hangs, no panics;
//! * the achieved quorum of every round equals the [`FaultPlan`]'s
//!   prediction exactly (faults are deterministic: delayed workers skip
//!   the send, killed workers drop the connection);
//! * honest full-quorum runs are **bit-exact** with the lockstep
//!   drivers (`run_sequential` / `run_threaded`) — the elastic engine
//!   routes full arrivals through the very same aggregation path;
//! * under-floor rounds and unsupported strategies produce named
//!   errors, not corrupted training.

use dlion::cluster::chaos::{run_chaos, CatchUpPath, ChaosTransport, FaultPlan, RejoinRecord};
use dlion::cluster::topology::Topology;
use dlion::cluster::{run_sequential, run_threaded, TrainConfig};
use dlion::optim::dist::faulty::Fault;
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::tasks::quadratic::Quadratic;
use dlion::tasks::GradTask;
use std::sync::Arc;

const STRATEGIES: [&str; 3] = ["d-lion-mavo", "g-lion", "d-lion-ef"];
const TOPOLOGIES: [Topology; 2] = [Topology::Star, Topology::Hierarchical { group_size: 4 }];
const TRANSPORTS: [ChaosTransport; 2] = [ChaosTransport::InProc, ChaosTransport::Tcp];

fn task_arc(d: usize, seed: u64) -> Arc<dyn GradTask + Send + Sync> {
    Arc::new(Quadratic::new(d, 10.0, 0.5, seed))
}

fn chaos_cfg(steps: usize, topology: Topology) -> TrainConfig {
    TrainConfig {
        steps,
        batch_per_worker: 8,
        base_lr: 0.01,
        eval_every: 0,
        seed: 7,
        check_replicas: true,
        topology,
        ..Default::default()
    }
}

#[test]
fn honest_chaos_is_bit_exact_with_lockstep_drivers() {
    // The control arm: a no-fault plan must reproduce the pre-elastic
    // engines bit-for-bit — both run_sequential and run_threaded, on
    // both topologies, over both transports, for all three families.
    let (n, d, steps) = (5usize, 48usize, 12usize);
    let hp = StrategyHyper::default();
    for name in STRATEGIES {
        for topology in TOPOLOGIES {
            let strat = by_name(name, &hp).unwrap();
            let cfg = chaos_cfg(steps, topology);
            let task = Quadratic::new(d, 10.0, 0.5, 3);
            let seq = run_sequential(&task, strat.as_ref(), n, &cfg);
            let (thr, _) = run_threaded(task_arc(d, 3), strat.as_ref(), n, &cfg);
            assert_eq!(
                seq.final_params, thr.final_params,
                "{name}/{topology}: lockstep drivers disagree"
            );
            for transport in TRANSPORTS {
                let report = run_chaos(
                    task_arc(d, 3),
                    strat.as_ref(),
                    n,
                    &cfg,
                    &FaultPlan::honest(),
                    transport,
                )
                .unwrap_or_else(|e| panic!("{name}/{topology}/{transport:?}: {e}"));
                assert_eq!(
                    report.result.final_params, seq.final_params,
                    "{name}/{topology}/{transport:?}: honest chaos diverged from lockstep"
                );
                assert!(report.quorums.iter().all(|&q| q == n), "honest rounds must be full");
                assert_eq!(report.result.min_quorum(), Some(n as u64));
                assert_eq!(report.result.partial_rounds(), 0);
                assert_eq!(report.stats.round_count(), steps as u64);
                assert_eq!(report.stats.partial_round_count(), 0);
                // full-quorum byte accounting matches the sequential run
                assert_eq!(report.result.total_uplink(), seq.total_uplink());
                assert_eq!(report.result.total_downlink(), seq.total_downlink());
            }
        }
    }
}

#[test]
fn kill_delay_corrupt_matrix_completes_with_planned_quorums() {
    // One plan exercising all three fault kinds at once: worker 1 turns
    // Byzantine at round 2, worker 3 goes silent for rounds 3-4
    // (EF-folded comeback at round 5), worker 2 dies at round 5.
    let (n, d, steps) = (6usize, 40usize, 8usize);
    let hp = StrategyHyper::default();
    let plan = FaultPlan::new(0xFA11)
        .corrupt(1, 2, Fault::BitFlip)
        .delay(3, 3, 2)
        .kill(2, 5);
    for name in STRATEGIES {
        for topology in TOPOLOGIES {
            for transport in TRANSPORTS {
                let strat = by_name(name, &hp).unwrap();
                // A bit-flipped dense f32 payload may decode to NaN —
                // the unbounded-influence story the 1-bit vote exists to
                // avoid — and NaN breaks bitwise replica comparison
                // (NaN != NaN), so the dense family skips those asserts.
                let sign_family = name != "g-lion";
                let cfg = TrainConfig {
                    quorum: 3,
                    round_deadline_ms: 400,
                    check_replicas: sign_family,
                    ..chaos_cfg(steps, topology)
                };
                let report =
                    run_chaos(task_arc(d, 5), strat.as_ref(), n, &cfg, &plan, transport)
                        .unwrap_or_else(|e| panic!("{name}/{topology}/{transport:?}: {e}"));
                // achieved quorum per round is exactly the plan's prediction
                for (round, &q) in report.quorums.iter().enumerate() {
                    assert_eq!(
                        q,
                        plan.expected_quorum(n, round),
                        "{name}/{topology}/{transport:?}: round {round} quorum"
                    );
                }
                // ...and is mirrored into the per-step history + stats
                for (rec, &q) in report.result.history.iter().zip(&report.quorums) {
                    assert_eq!(rec.quorum, q as u64, "step {} record", rec.step);
                }
                assert_eq!(report.survivors, vec![0, 1, 3, 4, 5]);
                let expect_partials =
                    (0..steps).filter(|&r| plan.expected_quorum(n, r) < n).count();
                assert_eq!(report.result.partial_rounds(), expect_partials);
                assert_eq!(report.stats.partial_round_count(), expect_partials as u64);
                assert_eq!(
                    report.stats.quorum_total(),
                    report.quorums.iter().map(|&q| q as u64).sum::<u64>()
                );
                // sign-vote families bound the corrupt worker's
                // influence to ±1 vote per coordinate: params stay finite
                if sign_family {
                    let p = report.result.final_params.as_ref().unwrap();
                    assert!(p.iter().all(|x| x.is_finite()), "{name}: non-finite params");
                }
            }
        }
    }
}

#[test]
fn acceptance_n8_kill_two_delay_one() {
    // The issue's acceptance scenario: N=8, two workers killed at round
    // 3, one delayed by 2 rounds — completes on both drivers with the
    // per-round quorum recorded in StepRecord.
    let (n, d, steps) = (8usize, 48usize, 8usize);
    let plan = FaultPlan::new(0xACCE).kill(5, 3).kill(6, 3).delay(2, 4, 2);
    let hp = StrategyHyper::default();
    let strat = by_name("d-lion-mavo", &hp).unwrap();
    for transport in TRANSPORTS {
        let cfg = TrainConfig {
            quorum: 5,
            round_deadline_ms: 400,
            ..chaos_cfg(steps, Topology::Star)
        };
        let report = run_chaos(task_arc(d, 9), strat.as_ref(), n, &cfg, &plan, transport)
            .unwrap_or_else(|e| panic!("{transport:?}: {e}"));
        assert_eq!(report.survivors.len(), 6);
        for (round, rec) in report.result.history.iter().enumerate() {
            assert_eq!(
                rec.quorum,
                plan.expected_quorum(n, round) as u64,
                "{transport:?}: round {round}"
            );
        }
        // rounds 0-2 full; rounds 3+ miss the two dead workers; rounds
        // 4-5 additionally miss the straggler
        assert_eq!(report.quorums[..3], [8, 8, 8]);
        assert_eq!(report.quorums[3], 6);
        assert_eq!(report.quorums[4], 5);
        assert_eq!(report.quorums[5], 5);
        assert_eq!(report.quorums[6], 6);
        assert_eq!(report.result.min_quorum(), Some(5));
    }
}

#[test]
fn quorum_floor_unmet_is_a_named_error() {
    let (n, d) = (4usize, 24usize);
    let plan = FaultPlan::new(1).kill(2, 1).kill(3, 1);
    let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
    for transport in TRANSPORTS {
        let cfg = TrainConfig { quorum: 3, ..chaos_cfg(4, Topology::Star) };
        let err = run_chaos(task_arc(d, 2), strat.as_ref(), n, &cfg, &plan, transport)
            .err()
            .expect("floor of 3 with 2 survivors must fail");
        let msg = err.to_string();
        assert!(msg.contains("quorum not met"), "{transport:?}: unnamed error: {msg}");
    }
}

#[test]
fn unsupported_strategy_rejects_partial_rounds_by_name() {
    // terngrad has no abstention/rescale semantics — a partial round
    // must be a named refusal, not silently-wrong math.
    let (n, d) = (3usize, 24usize);
    let plan = FaultPlan::new(2).kill(2, 1);
    let strat = by_name("terngrad", &StrategyHyper::default()).unwrap();
    let cfg = TrainConfig { quorum: 2, ..chaos_cfg(4, Topology::Star) };
    let err = run_chaos(task_arc(d, 2), strat.as_ref(), n, &cfg, &plan, ChaosTransport::InProc)
        .err()
        .expect("terngrad cannot close partial rounds");
    assert!(
        err.to_string().contains("cannot close a partial round"),
        "unnamed error: {err}"
    );
}

#[test]
fn delay_plan_without_deadline_is_rejected_up_front() {
    let plan = FaultPlan::new(3).delay(0, 1, 1);
    let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
    let cfg = TrainConfig { quorum: 1, ..chaos_cfg(4, Topology::Star) };
    let err = run_chaos(task_arc(16, 1), strat.as_ref(), 2, &cfg, &plan, ChaosTransport::InProc)
        .err()
        .expect("delay without a deadline would hang gather");
    assert!(err.to_string().contains("round_deadline_ms"), "unnamed error: {err}");
}

#[test]
fn rejoin_via_ring_catches_up_and_votes_from_the_rejoin_round() {
    // Worker 1 dies before round 2 and rejoins before round 5: the gap
    // (3 rounds) fits the default replay ring, so catch-up is a pure
    // ring replay — and the rejoined replica must end the run
    // bit-identical to the never-killed ones (check_replicas covers
    // all four workers, because a rejoined worker is a survivor).
    let (n, d, steps) = (4usize, 40usize, 8usize);
    let plan = FaultPlan::new(0x12E1).rejoin(1, 2, 5);
    let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
    let cfg = TrainConfig { quorum: 3, ..chaos_cfg(steps, Topology::Star) };
    let report = run_chaos(task_arc(d, 11), strat.as_ref(), n, &cfg, &plan, ChaosTransport::Tcp)
        .unwrap_or_else(|e| panic!("rejoin-via-ring: {e}"));
    // quorum dips exactly over the dead window [2, 5): the worker votes
    // again in its rejoin round itself
    for (round, &q) in report.quorums.iter().enumerate() {
        assert_eq!(q, plan.expected_quorum(n, round), "round {round} quorum");
    }
    assert_eq!(report.quorums, vec![4, 4, 3, 3, 3, 4, 4, 4]);
    assert_eq!(
        report.rejoins,
        vec![RejoinRecord { worker: 1, round: 5, replayed: 3, path: CatchUpPath::Ring }]
    );
    assert_eq!(report.survivors, vec![0, 1, 2, 3], "a rejoined worker survives");
    assert!(report.stats.replay() > 0, "ring replay is real wire traffic");
}

#[test]
fn rejoin_beyond_the_ring_restores_from_checkpoint_then_replays_the_tail() {
    // A 9-round gap over a 4-deep ring: the driver must restore the
    // replica from the periodic server-side checkpoint at round 8 (the
    // newest multiple of the ring depth) and replay only the 2-round
    // ring tail. Replica equality still holds bit-exactly.
    let (n, d, steps) = (4usize, 40usize, 12usize);
    let plan = FaultPlan::new(0x12E2).rejoin(2, 1, 10);
    let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
    let cfg = TrainConfig {
        quorum: 3,
        replay_ring: 4,
        ..chaos_cfg(steps, Topology::Star)
    };
    let report = run_chaos(task_arc(d, 13), strat.as_ref(), n, &cfg, &plan, ChaosTransport::Tcp)
        .unwrap_or_else(|e| panic!("rejoin-beyond-ring: {e}"));
    for (round, &q) in report.quorums.iter().enumerate() {
        assert_eq!(q, plan.expected_quorum(n, round), "round {round} quorum");
    }
    assert_eq!(
        report.rejoins,
        vec![RejoinRecord {
            worker: 2,
            round: 10,
            replayed: 2,
            path: CatchUpPath::Checkpoint { from: 8 },
        }]
    );
    assert_eq!(report.survivors, vec![0, 1, 2, 3]);
}

#[test]
fn rejoin_restrictions_are_named_errors() {
    let strat = by_name("d-lion-mavo", &StrategyHyper::default()).unwrap();
    let plan = FaultPlan::new(4).rejoin(0, 1, 3);
    let cfg = TrainConfig { quorum: 1, ..chaos_cfg(6, Topology::Star) };

    // the reconnect handshake lives in comm::tcp — in-proc can't rejoin
    let err = run_chaos(task_arc(16, 1), strat.as_ref(), 2, &cfg, &plan, ChaosTransport::InProc)
        .err()
        .expect("rejoin over in-proc must be refused");
    assert!(err.to_string().contains("TCP transport"), "unnamed error: {err}");

    // catch-up replays whole wire rounds — local-steps schedules can't
    let local = by_name("d-lion-local(3)", &StrategyHyper::default()).unwrap();
    let err = run_chaos(task_arc(16, 1), local.as_ref(), 2, &cfg, &plan, ChaosTransport::Tcp)
        .err()
        .expect("rejoin with local steps must be refused");
    assert!(err.to_string().contains("local_steps == 1"), "unnamed error: {err}");

    // an empty replay ring leaves nothing to catch up from
    let no_ring = TrainConfig { replay_ring: 0, ..cfg.clone() };
    let err = run_chaos(task_arc(16, 1), strat.as_ref(), 2, &no_ring, &plan, ChaosTransport::Tcp)
        .err()
        .expect("rejoin with replay_ring 0 must be refused");
    assert!(err.to_string().contains("replay_ring"), "unnamed error: {err}");

    // a rejoin past the end of the run can never happen
    let late = FaultPlan::new(4).rejoin(0, 1, 99);
    let err = run_chaos(task_arc(16, 1), strat.as_ref(), 2, &cfg, &late, ChaosTransport::Tcp)
        .err()
        .expect("rejoin beyond the run must be refused");
    assert!(err.to_string().contains("rejoins at round 99"), "unnamed error: {err}");
}

#[test]
fn local_steps_chaos_closes_windowed_quorums_exactly() {
    // d-lion-local(3): one wire round per 3-step window. Worker 2 is
    // delayed at step 4 — inside the window ending at sync step 5 — so
    // it abstains that whole window (vote carry) and is back for the
    // window ending at 8. The wire-round quorums must match the
    // windowed plan queries, and all replicas (including the abstainer)
    // must agree bit-exactly at the end.
    let (n, d, steps, h) = (4usize, 40usize, 9usize, 3usize);
    let plan = FaultPlan::new(0x10CA).delay(2, 4, 1);
    let strat = by_name("d-lion-local(3)", &StrategyHyper::default()).unwrap();
    for topology in TOPOLOGIES {
        for transport in TRANSPORTS {
            let cfg = TrainConfig {
                quorum: 2,
                round_deadline_ms: 400,
                ..chaos_cfg(steps, topology)
            };
            let report = run_chaos(task_arc(d, 17), strat.as_ref(), n, &cfg, &plan, transport)
                .unwrap_or_else(|e| panic!("{topology}/{transport:?}: {e}"));
            for (step, &q) in report.quorums.iter().enumerate() {
                let expect = if (step + 1) % h == 0 {
                    plan.expected_quorum_windowed(n, step, h)
                } else {
                    0 // local phase: no wire round
                };
                assert_eq!(q, expect, "{topology}/{transport:?}: step {step} quorum");
            }
            assert_eq!(
                report.quorums,
                vec![0, 0, 4, 0, 0, 3, 0, 0, 4],
                "{topology}/{transport:?}: windowed quorum trace"
            );
            let p = report.result.final_params.as_ref().unwrap();
            assert!(p.iter().all(|x| x.is_finite()));
        }
    }
}

#[test]
fn honest_local_steps_chaos_is_bit_exact_with_run_threaded() {
    // The local-steps control arm: a no-fault elastic run must
    // reproduce the lockstep local-steps driver bit-for-bit.
    let (n, d, steps) = (4usize, 48usize, 9usize);
    let strat = by_name("d-lion-local(3)", &StrategyHyper::default()).unwrap();
    let cfg = chaos_cfg(steps, Topology::Star);
    let (thr, _) = run_threaded(task_arc(d, 19), strat.as_ref(), n, &cfg);
    for transport in TRANSPORTS {
        let report =
            run_chaos(task_arc(d, 19), strat.as_ref(), n, &cfg, &FaultPlan::honest(), transport)
                .unwrap_or_else(|e| panic!("{transport:?}: {e}"));
        assert_eq!(
            report.result.final_params, thr.final_params,
            "{transport:?}: honest local-steps chaos diverged from run_threaded"
        );
        assert_eq!(report.quorums, vec![0, 0, 4, 0, 0, 4, 0, 0, 4]);
    }
}
