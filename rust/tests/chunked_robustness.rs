//! Round-trip and adversarial-input properties of the tag-15 chunked
//! envelope codec ([`dlion::comm::chunked`]): arbitrary chunk counts
//! round-trip exactly; truncations, corrupted length prefixes, unknown
//! inner tags, and plain byte soup all come back as *named* errors —
//! never a panic, and never a silently mis-framed decode. Seeded
//! property tests over the in-repo mini-framework (no proptest
//! offline).

use dlion::comm::chunked::{self, frames_payload_len, head_len, ChunkedError, TAG_CHUNKED};
use dlion::testing::{forall, forall_explain};
use dlion::util::Rng;

/// Codec tags a well-formed inner frame may lead with (1..=14).
const VALID_TAGS: [u8; 14] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14];

/// Generate a random well-formed frame set: 1..=max_frames frames,
/// random valid tags, 0..max_payload payload bytes each.
fn gen_frames(r: &mut Rng, max_frames: usize, max_payload: usize) -> Vec<Vec<u8>> {
    let count = 1 + r.below(max_frames);
    (0..count)
        .map(|_| {
            let tag = VALID_TAGS[r.below(VALID_TAGS.len())];
            let len = r.below(max_payload);
            let mut f = Vec::with_capacity(1 + len);
            f.push(tag);
            for _ in 0..len {
                f.push((r.next_u64() & 0xFF) as u8);
            }
            f
        })
        .collect()
}

#[test]
fn arbitrary_chunk_counts_round_trip_exactly() {
    forall_explain(0xC0DE, 150, |r| gen_frames(r, 300, 40), |frames| {
        let msg = chunked::pack(frames);
        let back = chunked::try_unpack(&msg).map_err(|e| format!("valid message: {e}"))?;
        if back.len() != frames.len() {
            return Err(format!("count {} != {}", back.len(), frames.len()));
        }
        for (i, (b, f)) in back.iter().zip(frames).enumerate() {
            if b != &f.as_slice() {
                return Err(format!("frame {i} mutated in transit"));
            }
        }
        // payload accounting is defined (and bounded by the physical
        // size) for every well-formed message
        let logical = chunked::payload_len(&msg);
        if logical > msg.len() {
            return Err(format!("payload_len {logical} exceeds physical {}", msg.len()));
        }
        // per-distinct-tag head accounting, cross-checked independently
        let mut seen = [false; 256];
        let mut expect = 0usize;
        for f in frames {
            let tag = f[0];
            if !seen[tag as usize] {
                seen[tag as usize] = true;
                expect += head_len(tag).min(f.len());
            }
            expect += f.len().saturating_sub(head_len(tag));
        }
        if frames.len() == 1 {
            expect = frames[0].len();
        }
        if frames_payload_len(frames) != expect {
            return Err(format!("accounting {} != {expect}", frames_payload_len(frames)));
        }
        Ok(())
    });
}

#[test]
fn every_strict_prefix_of_a_valid_message_is_a_named_error() {
    forall_explain(0xC0FE, 80, |r| {
        let frames = gen_frames(r, 12, 24);
        let msg = chunked::pack(&frames);
        let cut = r.below(msg.len());
        (msg, cut)
    }, |(msg, cut)| {
        match chunked::try_unpack(&msg[..*cut]) {
            Ok(_) => Err(format!("prefix of length {cut} of a {}B message parsed", msg.len())),
            // every failure is one of the named variants; Display always
            // renders (the CLI/test layers surface it verbatim)
            Err(e) => {
                if e.to_string().is_empty() {
                    Err("error must name the failure".into())
                } else {
                    Ok(())
                }
            }
        }
    });
}

#[test]
fn corrupted_length_prefixes_never_misframe() {
    // Payloads of 0xFF make any shifted length read astronomically
    // large, so shrinking one inner length must surface as a named
    // error (and growing one always does): the decoder never returns a
    // plausible-but-wrong framing.
    forall_explain(0xC0AD, 60, |r| {
        let count = 2 + r.below(6);
        let frames: Vec<Vec<u8>> = (0..count)
            .map(|_| {
                let mut f = vec![VALID_TAGS[r.below(VALID_TAGS.len())]];
                f.resize(f.len() + 4 + r.below(12), 0xFF);
                f
            })
            .collect();
        let victim = r.below(count);
        let delta_up = r.next_u64() & 1 == 0;
        (frames, victim, delta_up)
    }, |(frames, victim, delta_up)| {
        let msg = chunked::pack(frames);
        // locate the victim frame's 4-byte length prefix
        let mut off = 3usize;
        for f in &frames[..*victim] {
            off += 4 + f.len();
        }
        let mut corrupt = msg.clone();
        let old = u32::from_le_bytes([msg[off], msg[off + 1], msg[off + 2], msg[off + 3]]);
        let bad = if *delta_up { old + 1 } else { old - 1 };
        corrupt[off..off + 4].copy_from_slice(&bad.to_le_bytes());
        match chunked::try_unpack(&corrupt) {
            Ok(got) => Err(format!(
                "length {old}->{bad} on frame {victim} still framed ({} chunks)",
                got.len()
            )),
            Err(e) => {
                if e.to_string().is_empty() {
                    Err("unnamed error".into())
                } else {
                    Ok(())
                }
            }
        }
    });
}

#[test]
fn unknown_inner_tags_are_named_errors() {
    // tag 0, the envelope tag itself (no nesting), and anything above
    // the codec range must be rejected with the offending chunk + tag.
    for bad_tag in [0u8, TAG_CHUNKED, 16, 77, 255] {
        let msg = chunked::pack(&[vec![1u8, 0xAB], vec![bad_tag, 1, 2], vec![4u8, 9]]);
        match chunked::try_unpack(&msg) {
            Err(ChunkedError::UnknownTag { chunk, tag }) => {
                assert_eq!((chunk, tag), (1, bad_tag));
            }
            other => panic!("tag {bad_tag}: expected UnknownTag, got {other:?}"),
        }
        // the Option wrapper and payload accounting agree (fallback to
        // physical size, no panic)
        assert!(chunked::unpack(&msg).is_none());
        assert_eq!(chunked::payload_len(&msg), msg.len());
    }
    // empty inner frames carry no tag at all
    let msg = chunked::pack(&[vec![1u8, 2], vec![]]);
    assert_eq!(chunked::try_unpack(&msg), Err(ChunkedError::EmptyFrame { chunk: 1 }));
}

#[test]
fn byte_soup_never_panics() {
    // try_unpack / unpack / payload_len are total functions of the
    // input bytes: random soup (forced to look chunked half the time)
    // must decode to a named error or a well-formed frame list, and the
    // accounting must always be defined.
    forall(0x50FA, 400, |r| {
        let len = r.below(160);
        let mut msg: Vec<u8> = (0..len).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        if !msg.is_empty() && r.next_u64() & 1 == 0 {
            msg[0] = TAG_CHUNKED;
        }
        msg
    }, |msg| {
        let res = chunked::try_unpack(msg);
        let opt = chunked::unpack(msg);
        let pl = chunked::payload_len(msg);
        // Option mirrors Result; malformed accounting falls back to the
        // physical length; well-formed accounting never exceeds it
        let fallback_ok = res.is_ok() || !chunked::is_chunked(msg) || pl == msg.len();
        opt.is_some() == res.is_ok() && fallback_ok && pl <= msg.len().max(1)
    });
}

#[test]
fn mismatched_payload_declarations_are_detected_deterministically() {
    // Directed (non-random) cases for each named variant, asserting the
    // exact error text fragments the transport layer surfaces.
    let cases: Vec<(Vec<u8>, &str)> = vec![
        (vec![TAG_CHUNKED], "header"),
        (vec![TAG_CHUNKED, 2, 0, 1, 0, 0, 0, 1], "length prefix"),
        (vec![TAG_CHUNKED, 1, 0, 200, 0, 0, 0, 1, 2], "only"),
        ({
            let mut m = chunked::pack(&[vec![3u8, 1, 0, 5]]);
            m.extend_from_slice(&[9, 9]);
            m
        }, "trailing"),
    ];
    for (msg, fragment) in cases {
        let err = chunked::try_unpack(&msg).expect_err("malformed must fail");
        assert!(
            err.to_string().contains(fragment),
            "expected '{fragment}' in '{err}' for {msg:?}"
        );
    }
}
