//! Integration: strategies × cluster × tasks, end to end (no artifacts
//! needed — the PJRT-artifact integration lives in integration_runtime.rs).

use dlion::cluster::{run_sequential, run_threaded, TrainConfig};
use dlion::optim::dist::{by_name, StrategyHyper, ALL_STRATEGIES};
use dlion::tasks::data::VisionData;
use dlion::tasks::mlp::MlpVision;
use dlion::tasks::quadratic::Quadratic;
use dlion::tasks::GradTask;
use std::sync::Arc;

fn vision_task() -> MlpVision {
    let data = Arc::new(VisionData::generate(1500, 400, 1.6, 42));
    MlpVision::new(data, 32)
}

#[test]
fn dlion_matches_gadamw_on_vision_at_fraction_of_bandwidth() {
    // The paper's headline (Fig. 2 + Table 1): comparable accuracy,
    // ~30x less communication.
    let task = vision_task();
    let cfg = TrainConfig {
        steps: 500,
        batch_per_worker: 32,
        base_lr: 1e-3,
        eval_every: 0,
        seed: 42,
        ..Default::default()
    };
    let hp = StrategyHyper { weight_decay: 0.005, ..Default::default() };
    let dlion = by_name("d-lion-mavo", &hp).unwrap();
    let gadamw = by_name("g-adamw", &StrategyHyper { weight_decay: 0.0005, ..hp }).unwrap();
    let r_dlion = run_sequential(&task, dlion.as_ref(), 4, &cfg);
    let r_adamw = run_sequential(&task, gadamw.as_ref(), 4, &cfg);
    let acc_dlion = r_dlion.final_eval.unwrap().accuracy.unwrap();
    let acc_adamw = r_adamw.final_eval.unwrap().accuracy.unwrap();
    assert!(acc_dlion > acc_adamw - 0.05, "dlion {acc_dlion} vs adamw {acc_adamw}");
    let comm_ratio = (r_adamw.total_uplink() + r_adamw.total_downlink()) as f64
        / (r_dlion.total_uplink() + r_dlion.total_downlink()) as f64;
    assert!(comm_ratio > 20.0, "communication ratio only {comm_ratio:.1}x");
}

#[test]
fn dlion_beats_compression_baselines_at_matched_bandwidth() {
    // Fig. 4's shape: at ~matched (low) bandwidth D-Lion outperforms
    // TernGrad / GradDrop / DGC.
    let task = vision_task();
    let cfg = TrainConfig {
        steps: 500,
        batch_per_worker: 32,
        base_lr: 1e-3,
        eval_every: 0,
        seed: 52,
        ..Default::default()
    };
    let hp = StrategyHyper { weight_decay: 0.005, ..Default::default() };
    let dlion = by_name("d-lion-mavo", &hp).unwrap();
    let acc_dlion = run_sequential(&task, dlion.as_ref(), 4, &cfg)
        .final_eval
        .unwrap()
        .accuracy
        .unwrap();
    for name in ["terngrad", "graddrop", "dgc"] {
        let hp_c = StrategyHyper { weight_decay: 0.0005, ..Default::default() };
        let cfg_c = TrainConfig { base_lr: 5e-3, ..cfg.clone() };
        let strat = by_name(name, &hp_c).unwrap();
        let acc = run_sequential(&task, strat.as_ref(), 4, &cfg_c)
            .final_eval
            .unwrap()
            .accuracy
            .unwrap();
        assert!(
            acc_dlion > acc + 0.03,
            "{name}: dlion {acc_dlion:.3} should clearly beat {acc:.3}"
        );
    }
}

#[test]
fn replicas_identical_for_every_strategy_threaded() {
    // The replicated-parameter invariant over the real threaded fabric.
    for name in ALL_STRATEGIES {
        let task: Arc<dyn GradTask + Send + Sync> = Arc::new(Quadratic::new(200, 5.0, 0.5, 9));
        let hp = StrategyHyper::default();
        let strat = by_name(name, &hp).unwrap();
        let cfg = TrainConfig {
            steps: 25,
            batch_per_worker: 4,
            base_lr: 5e-3,
            eval_every: 0,
            seed: 1,
            check_replicas: true, // asserts equality at join
            ..Default::default()
        };
        let (_res, stats) = run_threaded(task, strat.as_ref(), 3, &cfg);
        assert!(stats.uplink() > 0 && stats.downlink() > 0, "{name} moved no bytes");
    }
}

#[test]
fn bandwidth_accounting_matches_analytic_table1() {
    // Invariant 8: transport-counted bytes == analytic prediction, for
    // the fixed-rate strategies (DGC's warmup makes it time-varying).
    let d = 10_000;
    for (name, n) in [
        ("d-lion-mavo", 5usize),
        ("d-lion-avg", 4),
        ("d-signum-mavo", 3),
        ("g-lion", 4),
        ("g-adamw", 2),
        ("terngrad", 4),
    ] {
        let task: Arc<dyn GradTask + Send + Sync> = Arc::new(Quadratic::new(d, 5.0, 0.5, 2));
        let hp = StrategyHyper::default();
        let strat = by_name(name, &hp).unwrap();
        let steps = 4;
        let cfg = TrainConfig {
            steps,
            batch_per_worker: 2,
            base_lr: 1e-3,
            eval_every: 0,
            seed: 5,
            ..Default::default()
        };
        let (_res, stats) = run_threaded(task, strat.as_ref(), n, &cfg);
        let up_bits_per_param = stats.uplink() as f64 * 8.0 / (d * n * steps) as f64;
        let down_bits_per_param = stats.downlink() as f64 * 8.0 / (d * n * steps) as f64;
        let up_pred = strat.uplink_bits_per_param(n);
        let down_pred = strat.downlink_bits_per_param(n);
        // small slack for frame headers (tag/N/scaler bytes)
        assert!(
            (up_bits_per_param - up_pred).abs() / up_pred < 0.02,
            "{name}: uplink {up_bits_per_param:.3} vs predicted {up_pred:.3}"
        );
        assert!(
            (down_bits_per_param - down_pred).abs() / down_pred < 0.02,
            "{name}: downlink {down_bits_per_param:.3} vs predicted {down_pred:.3}"
        );
    }
}

#[test]
fn worker_scaling_shapes_match_figure3() {
    // Fig. 3's qualitative claim: accuracy holds (degrades slowly) as k
    // grows; MaVo stays within a few points of G-Lion at every k.
    let task = vision_task();
    let hp = StrategyHyper { weight_decay: 0.005, ..Default::default() };
    let mavo = by_name("d-lion-mavo", &hp).unwrap();
    let glion = by_name("g-lion", &hp).unwrap();
    for k in [4usize, 16] {
        let cfg = TrainConfig {
            steps: 400,
            batch_per_worker: 32,
            base_lr: 5e-4,
            eval_every: 0,
            seed: 62,
            ..Default::default()
        };
        let a_mavo = run_sequential(&task, mavo.as_ref(), k, &cfg)
            .final_eval
            .unwrap()
            .accuracy
            .unwrap();
        let a_glion = run_sequential(&task, glion.as_ref(), k, &cfg)
            .final_eval
            .unwrap()
            .accuracy
            .unwrap();
        assert!(
            (a_mavo - a_glion).abs() < 0.08,
            "k={k}: mavo {a_mavo:.3} vs g-lion {a_glion:.3}"
        );
        assert!(a_mavo > 0.5, "k={k}: mavo collapsed to {a_mavo:.3}");
    }
}

#[test]
fn config_file_end_to_end() {
    // configs/*.toml drive the CLI path.
    let exp = dlion::config::Experiment::parse(
        r#"
name = "it"
task = "mlp-vision"
strategies = ["d-lion-avg"]
workers = [2]
seeds = [1]

[train]
steps = 60
lr = 0.001
eval_every = 0

[task]
hidden = 16
train_n = 400
test_n = 100
noise = 1.0
"#,
    )
    .unwrap();
    let task = exp.build_task(1).unwrap();
    let strat = by_name(&exp.strategies[0], &exp.hyper).unwrap();
    let res = run_sequential(task.as_ref(), strat.as_ref(), exp.workers[0], &exp.train);
    assert!(res.final_eval.unwrap().accuracy.unwrap() > 0.15);
}
