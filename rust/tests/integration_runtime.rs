//! Integration over the PJRT runtime + AOT artifacts (Invariant 10 and
//! the full three-layer composition). Gated on `artifacts/` existing —
//! run `make artifacts` first; tests are skipped (pass with a notice)
//! otherwise so plain `cargo test` works from a fresh checkout.

use dlion::cluster::{run_sequential, TrainConfig};
use dlion::lm::corpus::{Corpus, Grammar};
use dlion::lm::LmTask;
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::optim::lion::Lion;
use dlion::optim::LionParams;
use dlion::runtime::{LionUpdateExec, Runtime, TrainStepExec};
use dlion::tasks::GradTask;
use dlion::util::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("DLION_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime integration test: {dir}/manifest.json missing (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_and_executables_load() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    assert!(rt.manifest.flat_dim > 0);
    for name in ["train_step", "eval_step", "lion_update", "majority_vote", "apply_update"] {
        rt.executable(name).unwrap_or_else(|e| panic!("compile {name}: {e}"));
    }
}

#[test]
fn pallas_lion_kernel_matches_rust_bit_exact() {
    // Invariant 10: the L1 Pallas kernel and the L3 native optimizer
    // implement the same update, bit for bit on the binary output.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let lu = LionUpdateExec::new(&rt).unwrap();
    let d = lu.dim;
    let mut rng = Rng::new(0x777);
    for trial in 0..3 {
        let mut m = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut m, 0.1 * (trial + 1) as f32);
        rng.fill_normal(&mut g, 1.0);
        let (delta, m_new) = lu.run(&m, &g).unwrap();
        let mut lion = Lion::new(d, LionParams::default());
        lion.momentum.copy_from_slice(&m);
        let mut native_delta = vec![0.0f32; d];
        lion.peek_update(&g, &mut native_delta);
        lion.advance_momentum(&g);
        for k in 0..d {
            assert_eq!(delta[k] as f32, native_delta[k], "delta mismatch at {k}");
        }
        let max_err = m_new
            .iter()
            .zip(&lion.momentum)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-5, "momentum mismatch {max_err}");
    }
}

#[test]
fn train_step_gradients_are_finite_and_loss_sane() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let ts = TrainStepExec::new(&rt).unwrap();
    let init = std::fs::read(std::path::Path::new(&dir).join("params_init.bin")).unwrap();
    let params: Vec<f32> = init
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let tokens: Vec<i32> = (0..ts.batch * ts.seq_plus1).map(|i| (i * 7 % 251) as i32).collect();
    let mut grad = vec![0.0f32; rt.manifest.flat_dim];
    let loss = ts.run(&params, &tokens, &mut grad).unwrap();
    let vocab = rt.manifest.config_usize("vocab").unwrap() as f32;
    assert!((loss - vocab.ln()).abs() < 1.5, "init loss {loss} vs ln(vocab) {}", vocab.ln());
    assert!(grad.iter().all(|g| g.is_finite()));
    let gnorm: f32 = grad.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(gnorm > 1e-3, "gradient is zero");
}

#[test]
fn majority_vote_artifact_matches_rust_server() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let spec = rt.manifest.artifact("majority_vote").unwrap().clone();
    let n = spec.inputs[0].shape[0];
    let d = spec.inputs[0].shape[1];
    let mut rng = Rng::new(0x888);
    let deltas: Vec<i8> = (0..n * d)
        .map(|_| if rng.next_u64() & 1 == 0 { 1i8 } else { -1 })
        .collect();
    // artifact path
    let lit = rt.literal_i8(&deltas, &[n, d]).unwrap();
    let out = rt.run("majority_vote", &[lit]).unwrap();
    let agg: Vec<i8> = out[0].to_vec::<i8>().unwrap();
    // rust-native path
    let mut votes = vec![0i32; d];
    for w in 0..n {
        for k in 0..d {
            votes[k] += deltas[w * d + k] as i32;
        }
    }
    for k in 0..d {
        assert_eq!(agg[k] as i32, votes[k].signum(), "coord {k}");
    }
}

#[test]
fn lm_task_trains_through_full_stack() {
    // The composed system: corpus -> PJRT train_step -> D-Lion coordinator.
    let Some(dir) = artifacts_dir() else { return };
    let task = LmTask::new(&dir, 60_000, Grammar::default(), 1).unwrap();
    let hp = StrategyHyper { weight_decay: 0.1, ..Default::default() };
    let strat = by_name("d-lion-mavo", &hp).unwrap();
    let cfg = TrainConfig {
        steps: 30,
        base_lr: 1e-3,
        eval_every: 0,
        seed: 1,
        ..Default::default()
    };
    let res = run_sequential(&task, strat.as_ref(), 2, &cfg);
    let first = res.history.first().unwrap().train_loss;
    let fin = res.final_eval.unwrap().loss;
    assert!(fin < first - 0.5, "loss should drop: {first} -> {fin}");
    // 1-bit uplink: bytes/step/worker == ceil(d/8)
    let per = res.total_uplink() as usize / (30 * 2);
    assert_eq!(per, task.dim().div_ceil(8));
}

#[test]
fn apply_update_artifact_matches_rust_apply() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let d = rt.manifest.flat_dim;
    let mut rng = Rng::new(0x999);
    let mut x = vec![0.0f32; d];
    let mut delta = vec![0.0f32; d];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_signs(&mut delta);
    let (lr, wd) = (0.01f32, 0.1f32);
    let out = rt
        .run(
            "apply_update",
            &[
                rt.literal_f32(&x, &[d]).unwrap(),
                rt.literal_f32(&delta, &[d]).unwrap(),
                xla::Literal::scalar(lr),
                xla::Literal::scalar(wd),
            ],
        )
        .unwrap();
    let got: Vec<f32> = out[0].to_vec::<f32>().unwrap();
    let mut expect = x.clone();
    Lion::apply_aggregated(&mut expect, &delta, lr, wd);
    let max_err = got
        .iter()
        .zip(&expect)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-6, "apply mismatch {max_err}");
}

#[test]
fn corpus_round_trips_eval_batches() {
    // no artifacts needed, but lives here with the other LM pieces
    let c = Corpus::generate(50_000, Grammar::domain(3), 4);
    let batches = c.eval_batches(4, 65, 8);
    assert!(!batches.is_empty());
    for b in &batches {
        assert_eq!(b.len(), 4 * 65);
    }
}
