//! Integration over the runtime + artifact set (Invariant 10 and the
//! full three-layer composition). These tests run the **native**
//! backend live — no `artifacts/` directory, no skipping: a fresh
//! checkout's `cargo test` exercises the LM path end-to-end. Point
//! `DLION_ARTIFACTS` at an AOT set to run the same contracts through
//! PJRT instead.

use dlion::cluster::{run_sequential, TrainConfig};
use dlion::lm::corpus::{Corpus, Grammar};
use dlion::lm::LmTask;
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::optim::lion::Lion;
use dlion::optim::LionParams;
use dlion::runtime::{HostTensor, LionUpdateExec, Runtime, TrainStepExec};
use dlion::tasks::GradTask;
use dlion::util::Rng;

/// The artifacts directory under test: `DLION_ARTIFACTS` when set (an
/// AOT/PJRT set), else a path that does not exist so [`Runtime`] falls
/// back to the in-memory native backend.
fn runtime() -> Runtime {
    let dir = std::env::var("DLION_ARTIFACTS")
        .unwrap_or_else(|_| "does-not-exist/no-artifacts-here".into());
    Runtime::open_model(dir, "tiny").unwrap()
}

#[test]
fn manifest_and_artifacts_load() {
    let rt = runtime();
    assert!(rt.manifest.flat_dim > 0);
    assert!(!rt.backend_name().is_empty());
    for name in ["train_step", "eval_step", "lion_update", "majority_vote", "apply_update"] {
        rt.manifest.artifact(name).unwrap_or_else(|e| panic!("artifact {name}: {e}"));
    }
    assert!(rt.run("nonexistent_artifact", &[]).is_err());
}

#[test]
fn lion_update_artifact_matches_rust_bit_exact() {
    // Invariant 10: the artifact kernel and the L3 native optimizer
    // implement the same update, bit for bit on the binary output.
    let rt = runtime();
    let lu = LionUpdateExec::new(&rt).unwrap();
    let d = lu.dim;
    let mut rng = Rng::new(0x777);
    for trial in 0..3 {
        let mut m = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut m, 0.1 * (trial + 1) as f32);
        rng.fill_normal(&mut g, 1.0);
        let (delta, m_new) = lu.run(&m, &g).unwrap();
        let mut lion = Lion::new(d, LionParams::default());
        lion.momentum.copy_from_slice(&m);
        let mut native_delta = vec![0.0f32; d];
        lion.peek_update(&g, &mut native_delta);
        lion.advance_momentum(&g);
        for k in 0..d {
            assert_eq!(delta[k] as f32, native_delta[k], "delta mismatch at {k}");
        }
        let max_err = m_new
            .iter()
            .zip(&lion.momentum)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-5, "momentum mismatch {max_err}");
    }
}

#[test]
fn train_step_gradients_are_finite_and_loss_sane() {
    let rt = runtime();
    let ts = TrainStepExec::new(&rt).unwrap();
    let params = rt.init_params().unwrap();
    let tokens: Vec<i32> = (0..ts.batch * ts.seq_plus1).map(|i| (i * 7 % 251) as i32).collect();
    let mut grad = vec![0.0f32; rt.manifest.flat_dim];
    let loss = ts.run(&params, &tokens, &mut grad).unwrap();
    let vocab = rt.manifest.config_usize("vocab").unwrap() as f32;
    assert!((loss - vocab.ln()).abs() < 1.5, "init loss {loss} vs ln(vocab) {}", vocab.ln());
    assert!(grad.iter().all(|g| g.is_finite()));
    let gnorm: f32 = grad.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(gnorm > 1e-3, "gradient is zero");
}

#[test]
fn majority_vote_artifact_matches_rust_server() {
    let rt = runtime();
    let spec = rt.manifest.artifact("majority_vote").unwrap().clone();
    let n = spec.inputs[0].shape[0];
    let d = spec.inputs[0].shape[1];
    let mut rng = Rng::new(0x888);
    let deltas: Vec<i8> = (0..n * d)
        .map(|_| if rng.next_u64() & 1 == 0 { 1i8 } else { -1 })
        .collect();
    // artifact path
    let out = rt
        .run("majority_vote", &[HostTensor::i8(deltas.clone(), &[n, d])])
        .unwrap();
    let agg = out[0].as_i8().unwrap();
    // rust-native path
    let mut votes = vec![0i32; d];
    for w in 0..n {
        for k in 0..d {
            votes[k] += deltas[w * d + k] as i32;
        }
    }
    for k in 0..d {
        assert_eq!(agg[k] as i32, votes[k].signum(), "coord {k}");
    }
}

#[test]
fn lm_task_trains_through_full_stack() {
    // The composed system: corpus -> train_step artifact -> D-Lion
    // coordinator, live on a checkout with no artifacts directory.
    let task = LmTask::native("tiny", 60_000, Grammar::default(), 1).unwrap();
    let hp = StrategyHyper { weight_decay: 0.1, ..Default::default() };
    let strat = by_name("d-lion-mavo", &hp).unwrap();
    let cfg = TrainConfig {
        steps: 30,
        base_lr: 1e-3,
        eval_every: 0,
        seed: 1,
        ..Default::default()
    };
    let res = run_sequential(&task, strat.as_ref(), 2, &cfg);
    let first = res.history.first().unwrap().train_loss;
    let fin = res.final_eval.unwrap().loss;
    assert!(fin < first - 0.25, "loss should drop: {first} -> {fin}");
    // 1-bit uplink: bytes/step/worker == 1 tag byte + ceil(d/8) packed
    let per = res.total_uplink() as usize / (30 * 2);
    assert_eq!(per, 1 + task.dim().div_ceil(8));
}

#[test]
fn apply_update_artifact_matches_rust_apply() {
    let rt = runtime();
    let d = rt.manifest.flat_dim;
    let mut rng = Rng::new(0x999);
    let mut x = vec![0.0f32; d];
    let mut delta = vec![0.0f32; d];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_signs(&mut delta);
    let (lr, wd) = (0.01f32, 0.1f32);
    let out = rt
        .run(
            "apply_update",
            &[
                HostTensor::f32(x.clone(), &[d]),
                HostTensor::f32(delta.clone(), &[d]),
                HostTensor::scalar_f32(lr),
                HostTensor::scalar_f32(wd),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    let mut expect = x.clone();
    Lion::apply_aggregated(&mut expect, &delta, lr, wd);
    let max_err = got
        .iter()
        .zip(&expect)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-6, "apply mismatch {max_err}");
}

#[test]
fn corpus_round_trips_eval_batches() {
    // no runtime needed, but lives here with the other LM pieces
    let c = Corpus::generate(50_000, Grammar::domain(3), 4);
    let batches = c.eval_batches(4, 65, 8);
    assert!(!batches.is_empty());
    for b in &batches {
        assert_eq!(b.len(), 4 * 65);
    }
}
