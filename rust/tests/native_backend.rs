//! Native backend integration: the `gen-artifacts` pipeline round-trip
//! (source-hash caching, checksum verification) and the headline
//! acceptance test — the tiny GPT2++ LM trains end-to-end on the
//! pure-Rust backend under both a sign-voting and a dense-global
//! strategy, over star and hierarchical topologies, with zero skips.

use dlion::cluster::topology::Topology;
use dlion::cluster::{run_sequential, TrainConfig};
use dlion::lm::corpus::Grammar;
use dlion::lm::LmTask;
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::runtime::{native, Runtime};

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("dlion_native_backend_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn gen_artifacts_round_trip_and_cache() {
    let dir = temp_dir("gen");
    // fresh write
    let r1 = native::generate("tiny", &dir, 3, 4, false).unwrap();
    assert!(r1.fresh);
    assert_eq!(r1.manifest.backend, "native");
    assert!(dir.join("manifest.json").is_file());
    assert!(dir.join("params_init.bin").is_file());

    // unchanged inputs → cached no-op with the same source_hash
    let r2 = native::generate("tiny", &dir, 3, 4, false).unwrap();
    assert!(!r2.fresh, "unchanged source_hash must be a no-op");
    assert_eq!(r1.source_hash, r2.source_hash);

    // a changed seed changes the source_hash and regenerates
    let r3 = native::generate("tiny", &dir, 4, 4, false).unwrap();
    assert!(r3.fresh, "seed change must regenerate");
    assert_ne!(r1.source_hash, r3.source_hash);

    // --force regenerates even when cached
    let r4 = native::generate("tiny", &dir, 4, 4, true).unwrap();
    assert!(r4.fresh);

    // the generated set loads and trains through the Runtime
    let rt = Runtime::load(&dir).unwrap();
    assert_eq!(rt.backend_name(), "native");
    let init = rt.init_params().unwrap();
    assert_eq!(init.len(), rt.manifest.flat_dim);
    // params_init.bin must agree with the in-memory init for the seed
    assert_eq!(init, native::ModelCfg::by_name("tiny").unwrap().init_params(4));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_payload_fails_by_name() {
    let dir = temp_dir("corrupt");
    native::generate("tiny", &dir, 0, 4, false).unwrap();
    // truncate the payload: load must fail naming file + hashes
    std::fs::write(dir.join("params_init.bin"), b"truncated").unwrap();
    let err = Runtime::load(&dir).unwrap_err().to_string();
    assert!(err.contains("params_init.bin"), "error should name the payload: {err}");
    assert!(err.contains("checksum mismatch"), "{err}");
    // regeneration heals it (hash mismatch on disk → not a cache hit)
    let r = native::generate("tiny", &dir, 0, 4, false).unwrap();
    assert!(r.fresh, "corrupt checksums must force a rewrite");
    Runtime::load(&dir).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Train the tiny GPT2++ for `steps` rounds and return (first, final)
/// losses, asserting every recorded loss is finite.
fn train_lm(strategy: &str, workers: usize, steps: usize, topology: Topology) -> (f64, f64) {
    let task = LmTask::native("tiny", 60_000, Grammar::default(), 7).unwrap();
    let hp = StrategyHyper { weight_decay: 0.1, ..Default::default() };
    let strat = by_name(strategy, &hp).unwrap();
    let cfg = TrainConfig {
        steps,
        base_lr: 1e-3,
        eval_every: 0,
        seed: 7,
        topology,
        ..Default::default()
    };
    let res = run_sequential(&task, strat.as_ref(), workers, &cfg);
    assert!(
        res.history.iter().all(|r| r.train_loss.is_finite()),
        "{strategy}: non-finite train loss"
    );
    let first = res.history.first().unwrap().train_loss;
    let fin = res.final_eval.unwrap().loss;
    assert!(fin.is_finite(), "{strategy}: non-finite eval loss");
    if let Some(p) = &res.final_params {
        assert!(p.iter().all(|x| x.is_finite()), "{strategy}: non-finite params");
    }
    (first, fin)
}

#[test]
fn lm_native_trains_dlion_star() {
    let (first, fin) = train_lm("d-lion-mavo", 2, 50, Topology::Star);
    assert!(fin < first - 0.2, "d-lion-mavo star: loss should drop: {first} -> {fin}");
}

#[test]
fn lm_native_trains_gadamw_star() {
    let (first, fin) = train_lm("g-adamw", 2, 50, Topology::Star);
    assert!(fin < first - 0.2, "g-adamw star: loss should drop: {first} -> {fin}");
}

#[test]
fn lm_native_trains_dlion_hierarchical() {
    let (first, fin) = train_lm("d-lion-mavo", 4, 30, Topology::parse("hier:4").unwrap());
    assert!(fin < first - 0.15, "d-lion-mavo hier:4: loss should drop: {first} -> {fin}");
}

#[test]
fn lm_native_trains_gadamw_hierarchical() {
    let (first, fin) = train_lm("g-adamw", 4, 30, Topology::parse("hier:4").unwrap());
    assert!(fin < first - 0.15, "g-adamw hier:4: loss should drop: {first} -> {fin}");
}
