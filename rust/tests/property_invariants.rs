//! Cross-module property tests over the DESIGN.md invariant list,
//! using the in-repo testing mini-framework (no proptest offline).

use dlion::comm::{dense, half, intavg, sign, sparse, tern, varint};
use dlion::optim::dist::dlion::{Aggregation, DLion};
use dlion::optim::dist::{by_name, ServerLogic, Strategy, StrategyHyper, WorkerLogic};
use dlion::optim::lion::bsign;
use dlion::optim::{LionParams, Optimizer};
use dlion::testing::{forall, forall_explain, gen_vec_normal, gen_vec_sign, gen_vec_tern};
use dlion::theory;
use dlion::util::Rng;

#[test]
fn invariant1_codec_roundtrips() {
    forall(0xA01, 200, |r| gen_vec_sign(r, 0, 4096), |s| {
        sign::unpack(&sign::pack(s), s.len()) == *s
    });
    forall(0xA02, 200, |r| gen_vec_tern(r, 0, 4096, 0.3), |t| {
        tern::unpack(&tern::pack(t), t.len()) == *t
    });
    forall(0xA03, 200, |r| gen_vec_normal(r, 0, 2048, 100.0), |v| {
        dense::unpack(&dense::pack(v)) == *v
    });
    forall(0xA04, 100, |r| {
        let n = 1 + r.below(32);
        let d = r.below(512);
        let sums: Vec<i32> = (0..d)
            .map(|_| (0..n).map(|_| if r.next_u64() & 1 == 0 { 1 } else { -1 }).sum())
            .collect();
        (n, sums)
    }, |(n, sums)| intavg::unpack(&intavg::pack(sums, *n), sums.len(), *n) == *sums);
}

#[test]
fn invariant2_packed_sizes_exact() {
    forall_explain(0xA05, 100, |r| r.below(100_000), |&d| {
        let want = d.div_ceil(8);
        let got = sign::packed_len(d);
        if got == want {
            Ok(())
        } else {
            Err(format!("sign packed_len({d}) = {got}, want {want}"))
        }
    });
    forall_explain(0xA06, 100, |r| (1 + r.below(64), r.below(10_000)), |&(n, d)| {
        let bits = dlion::util::math::bits_for_count(n) as usize;
        let want = (d * bits).div_ceil(8);
        let got = intavg::packed_len(d, n);
        if got == want {
            Ok(())
        } else {
            Err(format!("intavg packed_len({d},{n}) = {got}, want {want}"))
        }
    });
}

#[test]
fn invariant1b_varint_half_sparse_roundtrips() {
    // varint: sorted index sets survive delta+LEB128 exactly, and the
    // decoder consumes exactly the bytes the encoder wrote.
    forall(0xA11, 150, |r| {
        let d = 1 + r.below(100_000);
        let k = 1 + r.below(d.min(400));
        r.sample_indices(d, k).into_iter().map(|i| i as u32).collect::<Vec<u32>>()
    }, |idx| {
        let mut buf = Vec::new();
        varint::pack_sorted_indices(idx, &mut buf);
        let mut back = Vec::new();
        varint::unpack_sorted_indices(&buf, idx.len(), &mut back) == Some(buf.len())
            && back == *idx
    });
    // half (bf16): decode∘encode is the identity on every non-NaN bf16
    // bit pattern, and encode∘decode stays within one bf16 ulp.
    for h in 0..=u16::MAX {
        if half::from_bf16_bits(h).is_nan() {
            continue;
        }
        assert_eq!(half::to_bf16_bits(half::from_bf16_bits(h)), h, "bf16 bits {h:#06x}");
    }
    forall(0xA12, 300, |r| r.normal_f32(0.0, 50.0), |&x| {
        let back = half::from_bf16_bits(half::to_bf16_bits(x));
        x == 0.0 || ((back - x) / x).abs() <= 1.0 / 256.0
    });
    // sparse: entry sets survive both the classic and compact formats.
    forall(0xA13, 150, |r| {
        let d = 1 + r.below(20_000);
        let k = r.below(d.min(300) + 1);
        let entries: Vec<sparse::Entry> = r
            .sample_indices(d, k)
            .into_iter()
            .map(|i| sparse::Entry { index: i as u32, value: r.normal_f32(0.0, 1.0) })
            .collect();
        (d, entries)
    }, |(d, entries)| {
        let classic = sparse::unpack(&sparse::pack(*d, entries));
        let compact = sparse::unpack_compact(&sparse::pack_compact(*d, entries));
        classic == (*d, entries.clone()) && compact == (*d, entries.clone())
    });
}

#[test]
fn invariant2b_packed_sizes_varint_half_sparse() {
    // half: exactly 16 bits/param.
    forall_explain(0xA14, 100, |r| r.below(10_000), |&d| {
        let v = vec![1.0f32; d];
        let got = half::pack(&v).len();
        if got == half::packed_len(d) && got == 2 * d {
            Ok(())
        } else {
            Err(format!("half pack({d}) = {got} bytes, want {}", 2 * d))
        }
    });
    // sparse classic: 64 header bits + 64 bits/entry, exactly.
    forall_explain(0xA15, 100, |r| {
        let d = 1 + r.below(5_000);
        let k = r.below(d.min(200) + 1);
        (d, k)
    }, |&(d, k)| {
        let entries: Vec<sparse::Entry> = (0..k)
            .map(|i| sparse::Entry { index: i as u32, value: 1.0 })
            .collect();
        let got = sparse::pack(d, &entries).len();
        let want = sparse::packed_len(k);
        if got == want && want == 8 + 8 * k {
            Ok(())
        } else {
            Err(format!("sparse pack(d={d}, k={k}) = {got} bytes, want {want}"))
        }
    });
    // varint: single-byte gaps for dense-ish selections (the DGC 4% regime
    // rides ~1 byte/index), never worse than 5 bytes/index.
    forall_explain(0xA16, 60, |r| {
        let d = 1_000 + r.below(100_000);
        let k = 1 + d / (20 + r.below(60));
        (d, k)
    }, |&(d, k)| {
        let mut rng = Rng::new((d + k) as u64);
        let idx: Vec<u32> = rng.sample_indices(d, k).into_iter().map(|i| i as u32).collect();
        let mut buf = Vec::new();
        varint::pack_sorted_indices(&idx, &mut buf);
        if buf.len() <= 5 * k {
            Ok(())
        } else {
            Err(format!("varint used {} bytes for {k} indices", buf.len()))
        }
    });
}

#[test]
fn invariant5_majority_vote_odd_under_flip() {
    // sign(Σ δ) must be an odd function of the worker updates.
    let hp = LionParams::default();
    forall(0xA07, 50, |r| {
        let n = 2 + r.below(6);
        let d = 1 + r.below(200);
        let grads: Vec<Vec<f32>> = (0..n).map(|_| gen_vec_normal(r, d, d, 1.0)).collect();
        grads
    }, |grads| {
        let n = grads.len();
        let d = grads[0].len();
        let run = |sgn: f32| -> Vec<u8> {
            let strat = DLion::new(hp, Aggregation::MajorityVote);
            let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
            let mut server = strat.make_server(n, d);
            let ups: Vec<_> = workers
                .iter_mut()
                .zip(grads)
                .map(|(w, g)| {
                    let gg: Vec<f32> = g.iter().map(|&x| sgn * x).collect();
                    w.encode(&gg, 1e-3, 0)
                })
                .collect();
            server.aggregate(&ups, 1e-3, 0)
        };
        let pos = run(1.0);
        let neg = run(-1.0);
        // decode both (tag-aware) and compare as trits
        let decode = |msg: &[u8]| -> Vec<i8> {
            match msg[0] {
                1 => sign::unpack(&msg[1..], d),
                2 => tern::unpack(&msg[1..], d),
                t => panic!("tag {t}"),
            }
        };
        let a = decode(&pos);
        let b = decode(&neg);
        // bsign(0)=+1 flips to -1 under negation, so strict oddness holds
        // except where the blend is exactly 0 — measure-zero for normals.
        a.iter().zip(&b).all(|(&x, &y)| x == -y)
    });
}

#[test]
fn invariant6_7_phase1_contraction_and_absorption() {
    // For iterates outside F, one Lion step contracts the distance by
    // (1−ελ) (up to the ε·Δ drift); once inside F with ελ small, the
    // iterate stays inside (Thm 4.4's absorption).
    forall_explain(0xA08, 30, |r| {
        let d = 4 + r.below(64);
        let lambda = 0.2 + r.uniform() as f32 * 0.8;
        let eps = 0.01 + r.uniform() as f32 * 0.05;
        let x0: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 10.0 / lambda)).collect();
        (lambda, eps, x0)
    }, |(lambda, eps, x0)| {
        let d = x0.len();
        let mut lion = dlion::optim::lion::Lion::new(
            d,
            LionParams { beta1: 0.9, beta2: 0.99, weight_decay: *lambda },
        );
        let mut x = x0.clone();
        let mut rng = Rng::new(1);
        let mut g = vec![0.0f32; d];
        let mut dists = Vec::new();
        let mut entered_at = None;
        for t in 0..300 {
            dists.push(theory::dist_to_feasible(&x, *lambda));
            if entered_at.is_none() && theory::in_feasible(&x, *lambda) {
                entered_at = Some(t);
            }
            rng.fill_normal(&mut g, 1.0);
            lion.step(&mut x, &g, *eps);
        }
        theory::check_phase1_contraction(&dists, (*eps * *lambda) as f64, 1.1)
            .map_err(|e| format!("λ={lambda} ε={eps}: {e}"))?;
        // absorption: after entering, never exits by more than the ε slab
        if let Some(s) = entered_at {
            for (t, &dist) in dists.iter().enumerate().skip(s) {
                if dist > (*eps * (1.0 + *lambda)) as f64 + 1e-6 {
                    return Err(format!("exited F at t={t}: dist={dist}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sparse_topk_threshold_property() {
    // Every kept entry's |value| >= every dropped entry's |value|.
    forall(0xA09, 100, |r| gen_vec_normal(r, 1, 500, 1.0), |v| {
        let k = (v.len() / 7).max(1);
        let entries = sparse::top_k(v, k);
        let kept: std::collections::HashSet<usize> =
            entries.iter().map(|e| e.index as usize).collect();
        let min_kept = entries.iter().map(|e| e.value.abs()).fold(f32::INFINITY, f32::min);
        v.iter()
            .enumerate()
            .filter(|(i, _)| !kept.contains(i))
            .all(|(_, &x)| x.abs() <= min_kept + 1e-6)
    });
}

#[test]
fn strategy_determinism_same_seed_same_bytes() {
    // Any strategy must be a deterministic function of (seed, grads):
    // identical runs produce identical downlinks (TernGrad's ternarization
    // rng is seeded per worker id).
    let hp = StrategyHyper::default();
    for name in ["d-lion-mavo", "d-lion-avg", "terngrad", "dgc", "g-lion"] {
        forall(0xA0A, 10, |r| {
            let d = 1 + r.below(128);
            let n = 1 + r.below(4);
            let grads: Vec<Vec<f32>> = (0..n).map(|_| gen_vec_normal(r, d, d, 1.0)).collect();
            grads
        }, |grads| {
            let n = grads.len();
            let d = grads[0].len();
            let run = || {
                let strat = by_name(name, &hp).unwrap();
                let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
                let mut server = strat.make_server(n, d);
                let ups: Vec<_> = workers
                    .iter_mut()
                    .zip(grads)
                    .map(|(w, g)| w.encode(g, 1e-3, 0))
                    .collect();
                server.aggregate(&ups, 1e-3, 0)
            };
            run() == run()
        });
    }
}

#[test]
fn invariant8_ef_residual_is_exactly_the_compression_error() {
    // d-lion-ef: replay the worker's recursion from public pieces. The
    // residual e_{t+1} = p_t − γ_t·sign(p_t) is by construction exactly
    // what the 1-bit frame dropped; if the worker's residual ever
    // deviated, its next frame would diverge from the replayed one.
    let hp = StrategyHyper::default();
    forall_explain(0xB01, 25, |r| {
        let d = 1 + r.below(300);
        let steps = 5 + r.below(40);
        let grads: Vec<Vec<f32>> = (0..steps).map(|_| gen_vec_normal(r, d, d, 1.0)).collect();
        grads
    }, |grads| {
        let d = grads[0].len();
        let strat = by_name("d-lion-ef", &hp).unwrap();
        let mut worker = strat.make_worker(0, 1, d);
        let mut momentum = vec![0.0f32; d];
        let mut error = vec![0.0f32; d];
        for (step, g) in grads.iter().enumerate() {
            let up = worker.encode(g, 1e-3, step);
            // p_t = β1·m + (1−β1)·g + e, from the externally-held state
            let p: Vec<f32> = momentum
                .iter()
                .zip(g)
                .zip(&error)
                .map(|((&m, &gg), &e)| hp.beta1 * m + (1.0 - hp.beta1) * gg + e)
                .collect();
            let expect = sign::pack_f32(&p);
            if up[1..] != expect[..] {
                return Err(format!("step {step}: EF frame diverged from residual recursion"));
            }
            let scale = (p.iter().map(|&x| x.abs() as f64).sum::<f64>() / d as f64) as f32;
            for (e, &pp) in error.iter_mut().zip(&p) {
                *e = pp - scale * bsign(pp);
            }
            for (m, &gg) in momentum.iter_mut().zip(g) {
                *m = hp.beta2 * *m + (1.0 - hp.beta2) * gg;
            }
        }
        Ok(())
    });
}

#[test]
fn invariant9_msync_round_leaves_momenta_bitwise_equal() {
    // d-lion-msync: after a sync round every worker holds the decoded
    // bf16 mean momentum — bitwise equal across workers. Observed on the
    // wire: the next sync round's momentum payloads are identical when
    // the interleaving gradients are shared, and the payload equals the
    // re-advanced broadcast mean.
    forall_explain(0xB02, 20, |r| {
        let d = 1 + r.below(200);
        let n = 2 + r.below(4);
        let pre: Vec<Vec<f32>> = (0..n).map(|_| gen_vec_normal(r, d, d, 1.0)).collect();
        let shared = gen_vec_normal(r, d, d, 1.0);
        (pre, shared)
    }, |(pre, shared)| {
        let d = pre[0].len();
        let n = pre.len();
        let hp = StrategyHyper { msync_every: 2, ..Default::default() };
        let strat = by_name("d-lion-msync", &hp).unwrap();
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.1f32; d]; n];
        // step 0 (ordinary) + step 1 (sync) with per-worker grads.
        for step in 0..2 {
            let ups: Vec<Vec<u8>> = workers
                .iter_mut()
                .zip(pre)
                .map(|(w, g)| w.encode(g, 1e-2, step))
                .collect();
            let down = server.aggregate(&ups, 1e-2, step);
            for (w, p) in workers.iter_mut().zip(params.iter_mut()) {
                w.apply(p, &down, 1e-2, step);
            }
        }
        // step 2 ordinary with a shared gradient, step 3 sync: payloads
        // must be bitwise identical across workers.
        let grads = vec![shared.clone(); n];
        for (step, expect_equal) in [(2usize, false), (3usize, true)] {
            let ups: Vec<Vec<u8>> = workers
                .iter_mut()
                .zip(&grads)
                .map(|(w, g)| w.encode(g, 1e-2, step))
                .collect();
            if expect_equal {
                let off = 1 + sign::packed_len(d);
                for (w, up) in ups.iter().enumerate() {
                    if up[off..] != ups[0][off..] {
                        return Err(format!("worker {w}: momentum payload differs post-sync"));
                    }
                }
            }
            let down = server.aggregate(&ups, 1e-2, step);
            for (w, p) in workers.iter_mut().zip(params.iter_mut()) {
                w.apply(p, &down, 1e-2, step);
            }
        }
        Ok(())
    });
}

#[test]
fn invariant10_bandwidth_selector_never_exceeds_the_budget() {
    // The selector's cumulative measured traffic never exceeds the
    // configured link budget (bits/param/round, up+down, per worker) up
    // to frame-header slack — for any budget that affords the cheap arm.
    forall_explain(0xB03, 12, |r| {
        let d = 256 + r.below(2048);
        let n = 1 + 2 * r.below(3); // odd: 1, 3, 5
        let budget = 3.0 + r.uniform() * 61.0; // [3, 64): cheap=2 .. rich=64
        (d, n, budget)
    }, |&(d, n, budget)| {
        let hp = StrategyHyper { link_budget: budget as f32, ..Default::default() };
        let strat = by_name("bandwidth-aware(d-lion-mavo,g-lion)", &hp).unwrap();
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut server = strat.make_server(n, d);
        let mut params: Vec<Vec<f32>> = vec![vec![0.1f32; d]; n];
        let mut rng = Rng::new((d + n) as u64);
        let rounds = 40;
        let mut total_bits = 0.0f64;
        for step in 0..rounds {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut g = vec![0.0f32; d];
                    rng.fill_normal(&mut g, 1.0);
                    g
                })
                .collect();
            let (up, down) = dlion::optim::dist::run_round(
                &mut workers, server.as_mut(), &mut params, &grads, 1e-2, step,
            );
            // per-worker accounting, matching the analytic model
            total_bits += (up + down) as f64 * 8.0 / n as f64;
        }
        let spent = total_bits / (rounds as f64 * d as f64);
        // True-cap bound: the bucket accrues budget−cheap net credit per
        // round and every rich surcharge is fully funded from it, so
        // average spend ≤ cheap + (budget−cheap) = budget, up to
        // frame-header slack (all sampled budgets afford the 2-bit
        // cheap arm).
        if spent <= budget + 0.5 {
            Ok(())
        } else {
            Err(format!("d={d} n={n}: spent {spent:.3} bits/param/round vs budget {budget:.3}"))
        }
    });
}

#[test]
fn invariant11_per_link_mixed_selector_respects_both_hop_budgets() {
    // mixed(<cheap>@cheap,<rich>@rich): one token bucket per hop, both
    // fed by hyper.link_budget. Over a 200-round hierarchical run,
    // neither the worker edge (per worker) nor the agg→root hop (per
    // group) may spend more than max(budget, that hop's cheap floor)
    // bits/param/round, up to frame-header slack — and the worker-side
    // schedule replica must stay bitwise in sync with every server
    // replica (a desync flips one end to the other arm's frames, which
    // the servers' tag asserts and the replica check would catch).
    use dlion::cluster::topology::{RoundEngine, Topology};
    forall_explain(0xB04, 6, |r| {
        let d = 400 + 40 * r.below(16); // 40-aligned, 400..1000
        let budget = 3.0 + r.uniform() * 67.0; // [3, 70): spans cheap..rich
        (d, budget)
    }, |&(d, budget)| {
        let (n, group_size, rounds) = (4usize, 2usize, 200usize);
        let ngroups = n / group_size;
        let hp = StrategyHyper { link_budget: budget as f32, ..Default::default() };
        let strat = by_name("mixed(d-lion-mavo@cheap,g-lion@rich)", &hp)
            .map_err(|e| e.to_string())?;
        let topo = Topology::Hierarchical { group_size };
        let mut engine = RoundEngine::new(strat.as_ref(), n, d, topo, 40);
        let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
        let mut params: Vec<Vec<f32>> = vec![vec![0.1f32; d]; n];
        let mut rng = Rng::new(d as u64 ^ 0xB04);
        let (mut edge_bytes, mut agg_bytes) = (0u64, 0u64);
        for step in 0..rounds {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut g = vec![0.0f32; d];
                    rng.fill_normal(&mut g, 1.0);
                    g
                })
                .collect();
            let ups = engine.encode_all(&mut workers, &grads, 1e-2, step);
            let (down, hops) = engine.aggregate(&ups, 1e-2, step);
            engine.apply_all(&mut workers, &mut params, &down, 1e-2, step);
            edge_bytes += (hops.uplink + hops.downlink) as u64;
            agg_bytes += (hops.agg_uplink + hops.agg_downlink) as u64;
            for w in 1..n {
                if params[0] != params[w] {
                    return Err(format!(
                        "budget {budget:.2} d={d}: replica divergence at round {step} \
                         (worker/server schedules out of sync)"
                    ));
                }
            }
        }
        let edge_spent = edge_bytes as f64 * 8.0 / (n * rounds * d) as f64;
        let agg_spent = agg_bytes as f64 * 8.0 / (ngroups * rounds * d) as f64;
        // hop floors: even-N mavo edge = 1 + 1.6; agg = ⌈log2(3)⌉-bit
        // vote partial for a 2-worker group + the 1.6-bit broadcast
        let edge_cap = budget.max(1.0 + 1.6) + 0.5;
        let agg_cap = budget.max(2.0 + 1.6) + 0.5;
        if edge_spent > edge_cap {
            return Err(format!(
                "budget {budget:.2} d={d}: worker edge spent {edge_spent:.3} bits/param/round"
            ));
        }
        if agg_spent > agg_cap {
            return Err(format!(
                "budget {budget:.2} d={d}: agg hop spent {agg_spent:.3} bits/param/round"
            ));
        }
        Ok(())
    });
}

/// Shared body of invariant 12: aggregate a quorum of `votes.len()`
/// 1-bit ballots on a server sized for `n` workers via the elastic
/// path, and on a server sized for exactly the quorum via the lockstep
/// path — the downlinks must be byte-identical.
fn check_abstention(
    strat: &dyn Strategy,
    n: usize,
    d: usize,
    votes: &[Vec<i8>],
) -> Result<(), String> {
    let q = votes.len();
    let frames: Vec<Vec<u8>> = votes
        .iter()
        .map(|v| {
            let mut f = vec![1u8]; // TAG_SIGN
            f.extend_from_slice(&sign::pack(v));
            f
        })
        .collect();
    let refs: Vec<&[u8]> = frames.iter().map(|f| f.as_slice()).collect();
    let mut big = strat.make_server(n, d);
    let got = big.aggregate_quorum(&refs, 1e-3, 0);
    let mut small = strat.make_server(q, d);
    let want = small.aggregate(&frames, 1e-3, 0);
    if got == want {
        Ok(())
    } else {
        Err(format!(
            "{}: n={n} d={d} q={q}: quorum aggregate differs from the \
             vote over the quorum's payloads alone",
            strat.name()
        ))
    }
}

#[test]
fn invariant12_quorum_abstention_exactness() {
    // Elastic-round invariant: a vote over a quorum Q ⊆ N equals the
    // vote over Q's payloads alone — a missing voter abstains exactly,
    // it never becomes an implicit zero or a rescaled ghost. Checked
    // for both aggregations (majority vote and intavg mean). The two
    // seed blocks pin both server builds: odd-N servers carry the
    // VotePlanes SWAR accumulator (used whenever the achieved quorum
    // is an odd pure-majority count, with the threshold lowered to
    // ⌈q/2⌉), even-N servers only have the scalar i32 vote-sum path.
    for name in ["d-lion-mavo", "d-lion-avg"] {
        let strat = by_name(name, &StrategyHyper::default()).unwrap();
        forall_explain(0xB05, 40, |r| {
            let n = 3 + 2 * r.below(4); // odd cluster: 3, 5, 7, 9
            let d = 1 + r.below(700);
            let q = 1 + r.below(n);
            let votes: Vec<Vec<i8>> = (0..q).map(|_| gen_vec_sign(r, d, d)).collect();
            (n, d, votes)
        }, |(n, d, votes)| check_abstention(strat.as_ref(), *n, *d, votes));
        forall_explain(0xB06, 30, |r| {
            let n = 4 + 2 * r.below(3); // even cluster: 4, 6, 8
            let d = 1 + r.below(700);
            let q = 1 + r.below(n);
            let votes: Vec<Vec<i8>> = (0..q).map(|_| gen_vec_sign(r, d, d)).collect();
            (n, d, votes)
        }, |(n, d, votes)| check_abstention(strat.as_ref(), *n, *d, votes));
    }
}

#[test]
fn invariant13_straggler_fold_conserves_gradient_mass() {
    // EF-fold invariant: a straggler's residual carries the exact f32
    // sum of its missed gradients (same addition order as a sequential
    // accumulator), and take() drains it completely. With nothing
    // pending, take() must hand back the caller's own slice — no float
    // traffic at all — which is what keeps honest chaos runs bit-exact
    // with the lockstep drivers (even for -0.0 gradient entries).
    use dlion::cluster::chaos::StragglerFold;
    forall_explain(0xB07, 50, |r| {
        let d = 1 + r.below(500);
        let misses = 1 + r.below(4);
        let grads: Vec<Vec<f32>> =
            (0..=misses).map(|_| gen_vec_normal(r, d, d, 1.0)).collect();
        grads
    }, |grads| {
        let d = grads[0].len();
        let mut fold = StragglerFold::new(d);
        let first = fold.take(&grads[0]);
        if first.as_ptr() != grads[0].as_ptr() {
            return Err("take() with no pending residual must return the input slice".into());
        }
        let (last, missed) = grads.split_last().unwrap();
        for g in missed {
            fold.miss(g);
        }
        if !fold.pending() {
            return Err(format!("{} misses left nothing pending", missed.len()));
        }
        let mut acc = vec![0.0f32; d];
        for g in missed {
            for (a, &x) in acc.iter_mut().zip(g) {
                *a += x;
            }
        }
        let want: Vec<f32> = acc.iter().zip(last).map(|(&a, &x)| a + x).collect();
        if fold.take(last) != want.as_slice() {
            return Err(format!(
                "d={d}, {} misses: folded gradient is not the exact f32 sum",
                missed.len()
            ));
        }
        if fold.pending() {
            return Err("take() must clear the pending flag".into());
        }
        if fold.residual_mass() >= 1e-12 {
            return Err(format!("residual mass {} not drained by take()", fold.residual_mass()));
        }
        Ok(())
    });
}

#[test]
fn invariant14_abstained_windows_fold_exactly_into_the_next_frame() {
    // Local-steps vote carry: a worker that abstains k consecutive sync
    // windows ships, at its next sync, byte-for-byte the frame a worker
    // with one (k+1)·H-step window would ship over the same gradient
    // stream — abstention re-times the window's votes, it never
    // rewrites them. The frame is a pure function of the vote/momentum
    // recursion, so the reconciling applies the abstainer still runs in
    // between (rewinding params to each window base) must not leak into
    // it; and the replicas must stay bit-equal at every closed round.
    forall_explain(0xB08, 30, |r| {
        let h = 1 + r.below(4);
        let k = 1 + r.below(3);
        let d = 1 + r.below(300);
        let grads: Vec<Vec<Vec<f32>>> = (0..h * (k + 1))
            .map(|_| (0..2).map(|_| gen_vec_normal(r, d, d, 1.0)).collect())
            .collect();
        (h, k, grads)
    }, |(h, k, grads)| {
        let (h, k) = (*h, *k);
        let d = grads[0][0].len();
        let steps = h * (k + 1);
        let hp = StrategyHyper::default();
        let strat = by_name(&format!("d-lion-local({h})"), &hp).unwrap();
        let wide = by_name(&format!("d-lion-local({})", h * (k + 1)), &hp).unwrap();
        let mut w0 = strat.make_worker(0, 2, d); // always ships
        let mut w1 = strat.make_worker(1, 2, d); // abstains k windows
        let mut oracle = wide.make_worker(1, 2, d); // one wide window
        let mut server = strat.make_server(2, d);
        let mut p0 = vec![0.1f32; d];
        let mut p1 = vec![0.1f32; d];
        let mut po = vec![0.1f32; d];
        let lr = 0.01f32;
        for step in 0..steps {
            let (g0, g1) = (&grads[step][0], &grads[step][1]);
            let last = step + 1 == steps;
            if (step + 1) % h != 0 {
                w0.local_step(&mut p0, g0, lr, step);
                w1.local_step(&mut p1, g1, lr, step);
                oracle.local_step(&mut po, g1, lr, step);
                continue;
            }
            if last {
                let _ = w0.encode(g0, lr, step);
                let carried = w1.encode(g1, lr, step);
                let want = oracle.encode(g1, lr, step);
                if carried != want {
                    return Err(format!(
                        "h={h} k={k} d={d}: frame after {k} abstained windows \
                         differs from the single wide-window frame"
                    ));
                }
            } else {
                let up0 = w0.encode(g0, lr, step);
                w1.abstain_sync(g1, lr, step);
                oracle.local_step(&mut po, g1, lr, step);
                let down = server.aggregate_quorum(&[up0.as_slice()], lr, step);
                w0.apply(&mut p0, &down, lr, step);
                w1.apply(&mut p1, &down, lr, step);
                if p0 != p1 {
                    return Err(format!(
                        "h={h} k={k} d={d}: replicas diverged at abstained sync {step}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn bsign_never_zero() {
    forall(0xA0B, 500, |r| r.normal_f32(0.0, 1e-20), |&x| {
        let s = bsign(x);
        s == 1.0 || s == -1.0
    });
}
