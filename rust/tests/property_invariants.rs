//! Cross-module property tests over the DESIGN.md invariant list,
//! using the in-repo testing mini-framework (no proptest offline).

use dlion::comm::{dense, half, intavg, sign, sparse, tern, varint};
use dlion::optim::dist::dlion::{Aggregation, DLion};
use dlion::optim::dist::{by_name, Strategy, StrategyHyper};
use dlion::optim::lion::bsign;
use dlion::optim::{LionParams, Optimizer};
use dlion::testing::{forall, forall_explain, gen_vec_normal, gen_vec_sign, gen_vec_tern};
use dlion::theory;
use dlion::util::Rng;

#[test]
fn invariant1_codec_roundtrips() {
    forall(0xA01, 200, |r| gen_vec_sign(r, 0, 4096), |s| {
        sign::unpack(&sign::pack(s), s.len()) == *s
    });
    forall(0xA02, 200, |r| gen_vec_tern(r, 0, 4096, 0.3), |t| {
        tern::unpack(&tern::pack(t), t.len()) == *t
    });
    forall(0xA03, 200, |r| gen_vec_normal(r, 0, 2048, 100.0), |v| {
        dense::unpack(&dense::pack(v)) == *v
    });
    forall(0xA04, 100, |r| {
        let n = 1 + r.below(32);
        let d = r.below(512);
        let sums: Vec<i32> = (0..d)
            .map(|_| (0..n).map(|_| if r.next_u64() & 1 == 0 { 1 } else { -1 }).sum())
            .collect();
        (n, sums)
    }, |(n, sums)| intavg::unpack(&intavg::pack(sums, *n), sums.len(), *n) == *sums);
}

#[test]
fn invariant2_packed_sizes_exact() {
    forall_explain(0xA05, 100, |r| r.below(100_000), |&d| {
        let want = d.div_ceil(8);
        let got = sign::packed_len(d);
        if got == want {
            Ok(())
        } else {
            Err(format!("sign packed_len({d}) = {got}, want {want}"))
        }
    });
    forall_explain(0xA06, 100, |r| (1 + r.below(64), r.below(10_000)), |&(n, d)| {
        let bits = dlion::util::math::bits_for_count(n) as usize;
        let want = (d * bits).div_ceil(8);
        let got = intavg::packed_len(d, n);
        if got == want {
            Ok(())
        } else {
            Err(format!("intavg packed_len({d},{n}) = {got}, want {want}"))
        }
    });
}

#[test]
fn invariant1b_varint_half_sparse_roundtrips() {
    // varint: sorted index sets survive delta+LEB128 exactly, and the
    // decoder consumes exactly the bytes the encoder wrote.
    forall(0xA11, 150, |r| {
        let d = 1 + r.below(100_000);
        let k = 1 + r.below(d.min(400));
        r.sample_indices(d, k).into_iter().map(|i| i as u32).collect::<Vec<u32>>()
    }, |idx| {
        let mut buf = Vec::new();
        varint::pack_sorted_indices(idx, &mut buf);
        let mut back = Vec::new();
        varint::unpack_sorted_indices(&buf, idx.len(), &mut back) == Some(buf.len())
            && back == *idx
    });
    // half (bf16): decode∘encode is the identity on every non-NaN bf16
    // bit pattern, and encode∘decode stays within one bf16 ulp.
    for h in 0..=u16::MAX {
        if half::from_bf16_bits(h).is_nan() {
            continue;
        }
        assert_eq!(half::to_bf16_bits(half::from_bf16_bits(h)), h, "bf16 bits {h:#06x}");
    }
    forall(0xA12, 300, |r| r.normal_f32(0.0, 50.0), |&x| {
        let back = half::from_bf16_bits(half::to_bf16_bits(x));
        x == 0.0 || ((back - x) / x).abs() <= 1.0 / 256.0
    });
    // sparse: entry sets survive both the classic and compact formats.
    forall(0xA13, 150, |r| {
        let d = 1 + r.below(20_000);
        let k = r.below(d.min(300) + 1);
        let entries: Vec<sparse::Entry> = r
            .sample_indices(d, k)
            .into_iter()
            .map(|i| sparse::Entry { index: i as u32, value: r.normal_f32(0.0, 1.0) })
            .collect();
        (d, entries)
    }, |(d, entries)| {
        let classic = sparse::unpack(&sparse::pack(*d, entries));
        let compact = sparse::unpack_compact(&sparse::pack_compact(*d, entries));
        classic == (*d, entries.clone()) && compact == (*d, entries.clone())
    });
}

#[test]
fn invariant2b_packed_sizes_varint_half_sparse() {
    // half: exactly 16 bits/param.
    forall_explain(0xA14, 100, |r| r.below(10_000), |&d| {
        let v = vec![1.0f32; d];
        let got = half::pack(&v).len();
        if got == half::packed_len(d) && got == 2 * d {
            Ok(())
        } else {
            Err(format!("half pack({d}) = {got} bytes, want {}", 2 * d))
        }
    });
    // sparse classic: 64 header bits + 64 bits/entry, exactly.
    forall_explain(0xA15, 100, |r| {
        let d = 1 + r.below(5_000);
        let k = r.below(d.min(200) + 1);
        (d, k)
    }, |&(d, k)| {
        let entries: Vec<sparse::Entry> = (0..k)
            .map(|i| sparse::Entry { index: i as u32, value: 1.0 })
            .collect();
        let got = sparse::pack(d, &entries).len();
        let want = sparse::packed_len(k);
        if got == want && want == 8 + 8 * k {
            Ok(())
        } else {
            Err(format!("sparse pack(d={d}, k={k}) = {got} bytes, want {want}"))
        }
    });
    // varint: single-byte gaps for dense-ish selections (the DGC 4% regime
    // rides ~1 byte/index), never worse than 5 bytes/index.
    forall_explain(0xA16, 60, |r| {
        let d = 1_000 + r.below(100_000);
        let k = 1 + d / (20 + r.below(60));
        (d, k)
    }, |&(d, k)| {
        let mut rng = Rng::new((d + k) as u64);
        let idx: Vec<u32> = rng.sample_indices(d, k).into_iter().map(|i| i as u32).collect();
        let mut buf = Vec::new();
        varint::pack_sorted_indices(&idx, &mut buf);
        if buf.len() <= 5 * k {
            Ok(())
        } else {
            Err(format!("varint used {} bytes for {k} indices", buf.len()))
        }
    });
}

#[test]
fn invariant5_majority_vote_odd_under_flip() {
    // sign(Σ δ) must be an odd function of the worker updates.
    let hp = LionParams::default();
    forall(0xA07, 50, |r| {
        let n = 2 + r.below(6);
        let d = 1 + r.below(200);
        let grads: Vec<Vec<f32>> = (0..n).map(|_| gen_vec_normal(r, d, d, 1.0)).collect();
        grads
    }, |grads| {
        let n = grads.len();
        let d = grads[0].len();
        let run = |sgn: f32| -> Vec<u8> {
            let strat = DLion::new(hp, Aggregation::MajorityVote);
            let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, d)).collect();
            let mut server = strat.make_server(n, d);
            let ups: Vec<_> = workers
                .iter_mut()
                .zip(grads)
                .map(|(w, g)| {
                    let gg: Vec<f32> = g.iter().map(|&x| sgn * x).collect();
                    w.encode(&gg, 1e-3, 0)
                })
                .collect();
            server.aggregate(&ups, 1e-3, 0)
        };
        let pos = run(1.0);
        let neg = run(-1.0);
        // decode both (tag-aware) and compare as trits
        let decode = |msg: &[u8]| -> Vec<i8> {
            match msg[0] {
                1 => sign::unpack(&msg[1..], d),
                2 => tern::unpack(&msg[1..], d),
                t => panic!("tag {t}"),
            }
        };
        let a = decode(&pos);
        let b = decode(&neg);
        // bsign(0)=+1 flips to -1 under negation, so strict oddness holds
        // except where the blend is exactly 0 — measure-zero for normals.
        a.iter().zip(&b).all(|(&x, &y)| x == -y)
    });
}

#[test]
fn invariant6_7_phase1_contraction_and_absorption() {
    // For iterates outside F, one Lion step contracts the distance by
    // (1−ελ) (up to the ε·Δ drift); once inside F with ελ small, the
    // iterate stays inside (Thm 4.4's absorption).
    forall_explain(0xA08, 30, |r| {
        let d = 4 + r.below(64);
        let lambda = 0.2 + r.uniform() as f32 * 0.8;
        let eps = 0.01 + r.uniform() as f32 * 0.05;
        let x0: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 10.0 / lambda)).collect();
        (lambda, eps, x0)
    }, |(lambda, eps, x0)| {
        let d = x0.len();
        let mut lion = dlion::optim::lion::Lion::new(
            d,
            LionParams { beta1: 0.9, beta2: 0.99, weight_decay: *lambda },
        );
        let mut x = x0.clone();
        let mut rng = Rng::new(1);
        let mut g = vec![0.0f32; d];
        let mut dists = Vec::new();
        let mut entered_at = None;
        for t in 0..300 {
            dists.push(theory::dist_to_feasible(&x, *lambda));
            if entered_at.is_none() && theory::in_feasible(&x, *lambda) {
                entered_at = Some(t);
            }
            rng.fill_normal(&mut g, 1.0);
            lion.step(&mut x, &g, *eps);
        }
        theory::check_phase1_contraction(&dists, (*eps * *lambda) as f64, 1.1)
            .map_err(|e| format!("λ={lambda} ε={eps}: {e}"))?;
        // absorption: after entering, never exits by more than the ε slab
        if let Some(s) = entered_at {
            for (t, &dist) in dists.iter().enumerate().skip(s) {
                if dist > (*eps * (1.0 + *lambda)) as f64 + 1e-6 {
                    return Err(format!("exited F at t={t}: dist={dist}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sparse_topk_threshold_property() {
    // Every kept entry's |value| >= every dropped entry's |value|.
    forall(0xA09, 100, |r| gen_vec_normal(r, 1, 500, 1.0), |v| {
        let k = (v.len() / 7).max(1);
        let entries = sparse::top_k(v, k);
        let kept: std::collections::HashSet<usize> =
            entries.iter().map(|e| e.index as usize).collect();
        let min_kept = entries.iter().map(|e| e.value.abs()).fold(f32::INFINITY, f32::min);
        v.iter()
            .enumerate()
            .filter(|(i, _)| !kept.contains(i))
            .all(|(_, &x)| x.abs() <= min_kept + 1e-6)
    });
}

#[test]
fn strategy_determinism_same_seed_same_bytes() {
    // Any strategy must be a deterministic function of (seed, grads):
    // identical runs produce identical downlinks (TernGrad's ternarization
    // rng is seeded per worker id).
    let hp = StrategyHyper::default();
    for name in ["d-lion-mavo", "d-lion-avg", "terngrad", "dgc", "g-lion"] {
        forall(0xA0A, 10, |r| {
            let d = 1 + r.below(128);
            let n = 1 + r.below(4);
            let grads: Vec<Vec<f32>> = (0..n).map(|_| gen_vec_normal(r, d, d, 1.0)).collect();
            grads
        }, |grads| {
            let n = grads.len();
            let d = grads[0].len();
            let run = || {
                let strat = by_name(name, &hp).unwrap();
                let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, d)).collect();
                let mut server = strat.make_server(n, d);
                let ups: Vec<_> = workers
                    .iter_mut()
                    .zip(grads)
                    .map(|(w, g)| w.encode(g, 1e-3, 0))
                    .collect();
                server.aggregate(&ups, 1e-3, 0)
            };
            run() == run()
        });
    }
}

#[test]
fn bsign_never_zero() {
    forall(0xA0B, 500, |r| r.normal_f32(0.0, 1e-20), |&x| {
        let s = bsign(x);
        s == 1.0 || s == -1.0
    });
}
