//! Oracle parity suite for the vectorized codec kernels in
//! `dlion::comm::simd`: every dispatched path (portable 8-lane blocks,
//! SSE2, AVX2 — whichever this machine selects) must be bit-exact with
//! the retained scalar oracles, across awkward lengths, misaligned
//! sub-ranges, IEEE special values, and every practical intavg bit
//! width. The explicit per-tier tests at the bottom additionally pin
//! the portable and x86 paths directly, independent of dispatch.

use dlion::comm::{dense, half, intavg, simd, tern};
use dlion::util::Rng;

const LENS: [usize; 8] = [0, 1, 7, 8, 63, 64, 65, 1000];

/// Normal noise with IEEE specials injected: ±0.0, ±Inf, NaN, and a
/// denormal — the payloads that break shortcut implementations.
fn special_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 100.0);
    for x in v.iter_mut() {
        match rng.below(16) {
            0 => *x = 0.0,
            1 => *x = -0.0,
            2 => *x = f32::INFINITY,
            3 => *x = f32::NEG_INFINITY,
            4 => *x = f32::NAN,
            5 => *x = f32::from_bits(0x0000_0001), // smallest denormal
            _ => {}
        }
    }
    v
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// dense (f32 LE)
// ---------------------------------------------------------------------------

#[test]
fn dense_pack_matches_scalar_across_shapes() {
    let mut rng = Rng::new(0xD0);
    for d in LENS {
        let v = special_vec(&mut rng, d);
        assert_eq!(dense::pack(&v), dense::pack_scalar(&v), "d={d}");
    }
}

#[test]
fn dense_pack_matches_scalar_on_misaligned_subranges() {
    let mut rng = Rng::new(0xD1);
    let v = special_vec(&mut rng, 130);
    for sub in [&v[1..], &v[3..128], &v[5..6], &v[7..]] {
        assert_eq!(dense::pack(sub), dense::pack_scalar(sub));
    }
}

#[test]
fn dense_unpack_matches_scalar_across_shapes() {
    let mut rng = Rng::new(0xD2);
    for d in LENS {
        let payload = dense::pack_scalar(&special_vec(&mut rng, d));
        let mut fast = vec![0.0f32; d];
        let mut slow = vec![0.0f32; d];
        dense::unpack_into(&payload, &mut fast);
        dense::unpack_into_scalar(&payload, &mut slow);
        assert_eq!(bits(&fast), bits(&slow), "d={d}");
        assert_eq!(bits(&dense::unpack(&payload)), bits(&slow), "d={d}");
    }
}

#[test]
fn dense_accumulate_matches_scalar_bit_exact() {
    // Per-lane IEEE adds are never reassociated: the vector sum must be
    // bit-identical to the scalar one, specials included.
    let mut rng = Rng::new(0xD3);
    for d in LENS {
        let payload = dense::pack_scalar(&special_vec(&mut rng, d));
        let base = special_vec(&mut rng, d);
        let mut fast = base.clone();
        let mut slow = base;
        dense::accumulate(&payload, &mut fast);
        dense::accumulate_scalar(&payload, &mut slow);
        assert_eq!(bits(&fast), bits(&slow), "d={d}");
    }
}

#[test]
fn dense_accumulate_matches_scalar_on_misaligned_subranges() {
    let mut rng = Rng::new(0xD4);
    let v = special_vec(&mut rng, 130);
    let base = special_vec(&mut rng, 130);
    for (lo, hi) in [(1usize, 130usize), (3, 128), (5, 70)] {
        let payload = dense::pack_scalar(&v[lo..hi]);
        let mut fast = base[lo..hi].to_vec();
        let mut slow = base[lo..hi].to_vec();
        dense::accumulate(&payload, &mut fast);
        dense::accumulate_scalar(&payload, &mut slow);
        assert_eq!(bits(&fast), bits(&slow), "range {lo}..{hi}");
    }
}

#[test]
fn dense_pack_into_writes_at_analytic_offsets() {
    let mut rng = Rng::new(0xD5);
    let v = special_vec(&mut rng, 77);
    let mut out = vec![0u8; dense::packed_len(v.len())];
    dense::pack_into(&v, &mut out);
    assert_eq!(out, dense::pack_scalar(&v));
}

// ---------------------------------------------------------------------------
// half (bf16 RNE)
// ---------------------------------------------------------------------------

#[test]
fn bf16_round_matches_branchy_oracle_on_edge_patterns() {
    for b in [
        0u32,
        0x8000_0000, // -0.0
        0x3F80_8000, // tie, even mantissa -> stays
        0x3F81_8000, // tie, odd mantissa -> rounds up
        0x3F80_8001, // just above tie
        0x3F80_7FFF, // just below tie
        0x7F7F_FFFF, // f32::MAX -> overflows to +Inf in bf16
        0x7F80_0000, // +Inf
        0xFF80_0000, // -Inf
        0x7FC0_0000, // quiet NaN
        0x7F80_0001, // signaling NaN
        0xFFFF_FFFF,
        0x0000_0001, // denormal
        0x3F7F_FFFF,
    ] {
        let x = f32::from_bits(b);
        assert_eq!(simd::bf16_round(b), half::to_bf16_bits(x), "bits={b:#010X}");
    }
}

#[test]
fn half_pack_matches_scalar_across_shapes() {
    let mut rng = Rng::new(0xE0);
    for d in LENS {
        let v = special_vec(&mut rng, d);
        assert_eq!(half::pack(&v), half::pack_scalar(&v), "d={d}");
    }
}

#[test]
fn half_pack_matches_scalar_on_misaligned_subranges() {
    let mut rng = Rng::new(0xE1);
    let v = special_vec(&mut rng, 130);
    for sub in [&v[1..], &v[3..128], &v[9..10]] {
        assert_eq!(half::pack(sub), half::pack_scalar(sub));
    }
}

#[test]
fn half_unpack_and_accumulate_match_scalar() {
    let mut rng = Rng::new(0xE2);
    for d in LENS {
        let payload = half::pack_scalar(&special_vec(&mut rng, d));
        let mut fast = vec![0.0f32; d];
        let mut slow = vec![0.0f32; d];
        half::unpack_into(&payload, &mut fast);
        half::unpack_into_scalar(&payload, &mut slow);
        assert_eq!(bits(&fast), bits(&slow), "unpack d={d}");

        let base = special_vec(&mut rng, d);
        let mut afast = base.clone();
        let mut aslow = base;
        half::accumulate(&payload, &mut afast);
        half::accumulate_scalar(&payload, &mut aslow);
        assert_eq!(bits(&afast), bits(&aslow), "accumulate d={d}");
    }
}

// ---------------------------------------------------------------------------
// intavg (8 ranks per u64 register)
// ---------------------------------------------------------------------------

/// Valid vote sums for n workers: |s| <= n, s ≡ n (mod 2).
fn vote_sums(rng: &mut Rng, d: usize, n: usize) -> Vec<i32> {
    (0..d)
        .map(|_| {
            let ups = rng.below(n + 1) as i32; // ups in 0..=n
            2 * ups - n as i32
        })
        .collect()
}

#[test]
fn intavg_parity_over_all_practical_worker_counts() {
    // n ∈ 1..=64 covers every bit width b ∈ 1..=7; the kernels must
    // match both scalar oracles and roundtrip exactly.
    let mut rng = Rng::new(0x1A0);
    for n in 1usize..=64 {
        for d in [0usize, 1, 7, 8, 9, 63, 64, 65, 257] {
            let sums = vote_sums(&mut rng, d, n);
            let packed = intavg::pack(&sums, n);
            assert_eq!(packed, intavg::pack_scalar(&sums, n), "pack n={n} d={d}");
            assert_eq!(packed, intavg::pack_naive(&sums, n), "naive n={n} d={d}");
            let mut fast = vec![0i32; d];
            let mut slow = vec![0i32; d];
            intavg::unpack_into(&packed, n, &mut fast);
            intavg::unpack_into_scalar(&packed, n, &mut slow);
            assert_eq!(fast, slow, "unpack n={n} d={d}");
            assert_eq!(fast, sums, "roundtrip n={n} d={d}");
        }
    }
}

#[test]
fn intavg_parity_at_byte_width_and_beyond() {
    let mut rng = Rng::new(0x1A1);
    // n = 127/128/255 exercise b = 7/8; n = 300 exercises the b = 9
    // scalar fallback.
    for n in [127usize, 128, 255, 300] {
        for d in [1usize, 8, 65, 200] {
            let sums = vote_sums(&mut rng, d, n);
            let packed = intavg::pack(&sums, n);
            assert_eq!(packed, intavg::pack_naive(&sums, n), "pack n={n} d={d}");
            assert_eq!(intavg::unpack(&packed, d, n), sums, "roundtrip n={n} d={d}");
        }
    }
}

#[test]
fn range_codec_parity_with_scalar_oracles() {
    let mut rng = Rng::new(0x1A2);
    for (lo, hi) in [(-1i32, 1i32), (-4, 4), (-32, 32), (0, 255), (-128, 127), (-1000, 1000)] {
        for d in [0usize, 1, 7, 8, 63, 64, 65, 333] {
            let vals: Vec<i32> =
                (0..d).map(|_| lo + rng.below((hi - lo + 1) as usize) as i32).collect();
            let packed = intavg::pack_range(&vals, lo, hi);
            assert_eq!(
                packed,
                intavg::pack_range_scalar(&vals, lo, hi),
                "pack [{lo},{hi}] d={d}"
            );
            let mut slow = vec![0i32; d];
            intavg::unpack_range_scalar_into(&packed, lo, hi, &mut slow);
            assert_eq!(intavg::unpack_range(&packed, d, lo, hi), slow, "unpack [{lo},{hi}] d={d}");
            assert_eq!(slow, vals, "roundtrip [{lo},{hi}] d={d}");
        }
    }
}

// ---------------------------------------------------------------------------
// tern (5 trits per byte)
// ---------------------------------------------------------------------------

#[test]
fn tern_parity_with_scalar_oracles() {
    let mut rng = Rng::new(0x7E0);
    for d in LENS {
        let trits: Vec<i8> = (0..d).map(|_| rng.below(3) as i8 - 1).collect();
        let packed = tern::pack(&trits);
        assert_eq!(packed, tern::pack_scalar(&trits), "pack d={d}");
        let mut fast = vec![0i8; d];
        let mut slow = vec![0i8; d];
        tern::unpack_into(&packed, &mut fast);
        tern::unpack_into_scalar(&packed, &mut slow);
        assert_eq!(fast, slow, "unpack d={d}");
        assert_eq!(fast, trits, "roundtrip d={d}");
    }
}

#[test]
fn tern_unpack_matches_scalar_on_malformed_bytes() {
    // Bytes ≥ 243 are outside the 3^5 code space; the LUT must decode
    // them digit-for-digit like the scalar %3 chain (robustness parity:
    // a corrupt wire byte produces the same garbage on every tier).
    let packed: Vec<u8> = (240..=255u8).chain(0..=10).collect();
    let d = packed.len() * 5;
    let mut fast = vec![0i8; d];
    let mut slow = vec![0i8; d];
    tern::unpack_into(&packed, &mut fast);
    tern::unpack_into_scalar(&packed, &mut slow);
    assert_eq!(fast, slow);
}

// ---------------------------------------------------------------------------
// explicit per-tier pins (independent of what dispatch selects)
// ---------------------------------------------------------------------------

#[test]
fn portable_tier_matches_scalars_directly() {
    let mut rng = Rng::new(0x9E0);
    for d in LENS {
        let v = special_vec(&mut rng, d);
        let payload = dense::pack_scalar(&v);
        let base = special_vec(&mut rng, d);

        let mut fast = base.clone();
        let mut slow = base.clone();
        simd::dense_accumulate_portable(&payload, &mut fast);
        dense::accumulate_scalar(&payload, &mut slow);
        assert_eq!(bits(&fast), bits(&slow), "dense acc d={d}");

        let mut hout = vec![0u8; half::packed_len(d)];
        simd::bf16_pack_into_portable(&v, &mut hout);
        assert_eq!(hout, half::pack_scalar(&v), "bf16 pack d={d}");

        let mut hfast = vec![0.0f32; d];
        let mut hslow = vec![0.0f32; d];
        simd::bf16_unpack_into_portable(&hout, &mut hfast);
        half::unpack_into_scalar(&hout, &mut hslow);
        assert_eq!(bits(&hfast), bits(&hslow), "bf16 unpack d={d}");

        let mut bfast = base.clone();
        let mut bslow = base.clone();
        simd::bf16_accumulate_portable(&hout, &mut bfast);
        half::accumulate_scalar(&hout, &mut bslow);
        assert_eq!(bits(&bfast), bits(&bslow), "bf16 acc d={d}");
    }
}

#[cfg(target_arch = "x86_64")]
#[test]
fn x86_tiers_match_scalars_directly() {
    let mut rng = Rng::new(0x9E1);
    for d in LENS {
        let v = special_vec(&mut rng, d);
        let payload = dense::pack_scalar(&v);
        let base = special_vec(&mut rng, d);

        // SSE2 is architectural on x86-64.
        let mut fast = base.clone();
        let mut slow = base.clone();
        simd::x86::dense_accumulate_sse2(&payload, &mut fast);
        dense::accumulate_scalar(&payload, &mut slow);
        assert_eq!(bits(&fast), bits(&slow), "sse2 dense acc d={d}");

        if std::is_x86_feature_detected!("avx2") {
            let mut afast = base.clone();
            // SAFETY: AVX2 support verified by the runtime check above.
            unsafe { simd::x86::dense_accumulate_avx2(&payload, &mut afast) };
            assert_eq!(bits(&afast), bits(&slow), "avx2 dense acc d={d}");

            let mut hout = vec![0u8; half::packed_len(d)];
            // SAFETY: AVX2 support verified above.
            unsafe { simd::x86::bf16_pack_into_avx2(&v, &mut hout) };
            assert_eq!(hout, half::pack_scalar(&v), "avx2 bf16 pack d={d}");

            let mut hfast = vec![0.0f32; d];
            let mut hslow = vec![0.0f32; d];
            // SAFETY: AVX2 support verified above.
            unsafe { simd::x86::bf16_unpack_into_avx2(&hout, &mut hfast) };
            half::unpack_into_scalar(&hout, &mut hslow);
            assert_eq!(bits(&hfast), bits(&hslow), "avx2 bf16 unpack d={d}");

            let mut bfast = base.clone();
            let mut bslow = base.clone();
            // SAFETY: AVX2 support verified above.
            unsafe { simd::x86::bf16_accumulate_avx2(&hout, &mut bfast) };
            half::accumulate_scalar(&hout, &mut bslow);
            assert_eq!(bits(&bfast), bits(&bslow), "avx2 bf16 acc d={d}");
        }
    }
}

#[test]
fn dispatch_reports_a_named_tier() {
    let a = simd::active();
    assert!(!a.name().is_empty());
    #[cfg(target_arch = "x86_64")]
    assert!(a >= simd::Lanes::Sse2, "x86-64 must select at least SSE2");
}
