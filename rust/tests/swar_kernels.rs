//! Cross-layer property tests pinning the SWAR hot kernels bit-exact
//! against their scalar oracles, from raw slices up through the round
//! engine: the 8-lane sign gather vs the per-bit pack, the bit-sliced
//! majority vote vs i32 LUT vote sums, the fused Lion/Signum slice
//! kernels vs their decomposed 3-pass forms on misaligned sub-ranges
//! (±0.0 included), and the engine's (worker × chunk)-parallel
//! zero-copy envelope assembly vs the sequential per-worker paths.

use dlion::cluster::topology::{RoundEngine, Topology};
use dlion::comm::{chunked, sign};
use dlion::optim::dist::{by_name, SignKernel, StrategyHyper, TAG_SIGN};
use dlion::optim::signum::Signum;
use dlion::optim::LionParams;
use dlion::util::parallel::PAR_MIN_ELEMS;
use dlion::util::Rng;

/// Normal noise with ±0.0 injected (the packed-sign edge case: +0.0
/// must encode as +1, −0.0 as −1).
fn noisy_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 1.0);
    for x in v.iter_mut() {
        match rng.below(16) {
            0 => *x = 0.0,
            1 => *x = -0.0,
            _ => {}
        }
    }
    v
}

#[test]
fn swar_pack_matches_scalar_oracle_across_shapes() {
    let mut rng = Rng::new(0x51A4);
    for d in [0usize, 1, 7, 8, 63, 64, 65, 1_000_003] {
        let v = noisy_vec(&mut rng, d);
        assert_eq!(sign::pack_f32(&v), sign::pack_f32_scalar(&v), "d={d}");
    }
}

#[test]
fn sign_vote_server_swar_downlink_matches_i32_lut_oracle() {
    // Odd-N majority vote runs on the bit-plane accumulator; every
    // downlink bit must equal [i32 vote sum > 0] from the LUT path.
    let hp = StrategyHyper::default();
    let strat = by_name("d-lion-mavo", &hp).unwrap();
    let mut rng = Rng::new(0x5E4);
    for n in [1usize, 3, 5, 7, 9] {
        for d in [1usize, 7, 8, 63, 64, 65, 200] {
            let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
            let mut server = strat.make_server(n, d);
            let grads: Vec<Vec<f32>> = (0..n).map(|_| noisy_vec(&mut rng, d)).collect();
            let ups: Vec<_> =
                workers.iter_mut().zip(&grads).map(|(w, g)| w.encode(g, 1e-3, 0)).collect();
            let mut votes = vec![0i32; d];
            for up in &ups {
                sign::accumulate_votes(&up[1..], &mut votes);
            }
            let down = server.aggregate(&ups, 1e-3, 0);
            assert_eq!(down[0], TAG_SIGN, "odd-N downlink stays binary (n={n}, d={d})");
            assert_eq!(down.len(), 1 + sign::packed_len(d), "n={n}, d={d}");
            for (i, &v) in votes.iter().enumerate() {
                let bit = (down[1 + i / 8] >> (i % 8)) & 1;
                assert_eq!(bit == 1, v > 0, "lane {i}, n={n}, d={d}");
            }
        }
    }
}

#[test]
fn fused_slice_kernels_match_decomposed_oracles_on_subranges() {
    // The split-borrow kernels run on arbitrary chunk slices whose
    // starts are not byte-aligned in the original model; each must
    // reproduce the decomposed blend→scalar-pack→advance oracle (Lion)
    // and update_and_peek_range→scalar-pack (Signum) bit-for-bit.
    let mut rng = Rng::new(0xFA3);
    let d = 203;
    let hp = LionParams::default();
    let beta = 0.9f32;
    for range in [0..d, 0..40, 40..80, 80..d, 3..14, 13..77] {
        let momentum0 = noisy_vec(&mut rng, d);
        let grads = noisy_vec(&mut rng, d);
        let len = range.len();

        // Lion oracle: blend store, scalar pack, separate momentum pass.
        let mut m_oracle = momentum0.clone();
        let blend: Vec<f32> = m_oracle[range.clone()]
            .iter()
            .zip(&grads[range.clone()])
            .map(|(&m, &g)| hp.beta1 * m + (1.0 - hp.beta1) * g)
            .collect();
        let lion_expect = sign::pack_f32_scalar(&blend);
        for (m, &g) in m_oracle[range.clone()].iter_mut().zip(&grads[range.clone()]) {
            *m = hp.beta2 * *m + (1.0 - hp.beta2) * g;
        }
        let mut m_kern = momentum0.clone();
        let mut out = vec![0u8; sign::packed_len(len)];
        SignKernel::LionFused { beta1: hp.beta1, beta2: hp.beta2 }.encode(
            &mut m_kern[range.clone()],
            &grads[range.clone()],
            &mut out,
        );
        assert_eq!(out, lion_expect, "lion payload, range {range:?}");
        assert_eq!(m_kern, m_oracle, "lion momentum, range {range:?}");

        // Signum oracle: the pre-existing ranged advance-and-peek
        // (bsign preserves the IEEE sign bit, so packing the peeked
        // ±1s equals packing the momentum directly — −0.0 included).
        let mut sig = Signum::new(d, beta, 0.0);
        sig.momentum.copy_from_slice(&momentum0);
        let mut peek = vec![0.0f32; len];
        sig.update_and_peek_range(&grads, range.clone(), &mut peek);
        let sig_expect = sign::pack_f32_scalar(&peek);
        let mut m_sig = momentum0.clone();
        let mut out2 = vec![0u8; sign::packed_len(len)];
        SignKernel::Signum { beta }.encode(
            &mut m_sig[range.clone()],
            &grads[range.clone()],
            &mut out2,
        );
        assert_eq!(out2, sig_expect, "signum payload, range {range:?}");
        assert_eq!(m_sig, sig.momentum, "signum momentum, range {range:?}");
    }
}

#[test]
fn encode_planned_zero_copy_equals_collect_and_pack() {
    // The tag-15 envelope assembled in place at analytic offsets must
    // be byte-identical (headers included) to collecting encode_chunk
    // frames and splicing them with chunked::pack.
    let mut rng = Rng::new(0xE0E);
    let hp = StrategyHyper::default();
    let (n, d, chunk_size) = (2usize, 200usize, 40usize);
    for name in ["d-lion-mavo", "d-signum-mavo"] {
        let strat = by_name(name, &hp).unwrap();
        let plan = strat.plan(d, chunk_size);
        assert!(!plan.is_single(), "{name}: test needs a multi-chunk plan");
        let mut wa = strat.make_worker(0, n, d);
        let mut wb = strat.make_worker(0, n, d);
        for step in 0..3 {
            let g = noisy_vec(&mut rng, d);
            let zero_copy = wa.encode_planned(&g, &plan, 1e-3, step);
            let frames: Vec<Vec<u8>> =
                plan.chunks().map(|c| wb.encode_chunk(&g, c, 1e-3, step)).collect();
            assert_eq!(zero_copy, chunked::pack(&frames), "{name}, step {step}");
        }
    }
}

#[test]
fn engine_parallel_split_encode_matches_sequential_bytes() {
    // Above PAR_MIN_ELEMS the engine runs (worker × chunk)-parallel
    // split-borrow encode into recycled round buffers; every uplink must
    // equal the sequential per-worker encode_planned bytes, every round
    // (buffer reuse across rounds would leak stale bytes if a kernel
    // OR-ed instead of stored).
    let d = PAR_MIN_ELEMS + 4_464; // 70_000: forces the parallel path
    let (n, chunk_size) = (3usize, 4_096usize);
    let hp = StrategyHyper::default();
    let strat = by_name("d-lion-mavo", &hp).unwrap();
    let mut engine = RoundEngine::new(strat.as_ref(), n, d, Topology::Star, chunk_size);
    let plan = engine.plan();
    assert!(plan.num_chunks() > 1, "test needs a multi-chunk plan");
    let mut workers: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
    let mut oracle: Vec<_> = (0..n).map(|i| strat.make_worker(i, n, d)).collect();
    let mut rng = Rng::new(0xE16);
    for step in 0..3 {
        let grads: Vec<Vec<f32>> = (0..n).map(|_| noisy_vec(&mut rng, d)).collect();
        let ups = engine.encode_all(&mut workers, &grads, 1e-3, step);
        for (i, (up, w)) in ups.iter().zip(oracle.iter_mut()).enumerate() {
            let expect = w.encode_planned(&grads[i], &plan, 1e-3, step);
            assert_eq!(up, &expect, "worker {i}, round {step}");
        }
        // odd N: the chunked aggregate runs per-chunk SWAR vote planes
        let (down, _) = engine.aggregate(&ups, 1e-3, step);
        assert_eq!(down[0], chunked::TAG_CHUNKED);
        engine.recycle_uplinks(ups);
    }
}
