//! Table-1 regression: each fixed-rate strategy's *measured* bits/param
//! from a short `run_sequential` must match the analytic formulas
//! documented in `rust/src/comm/mod.rs` (1-bit D-Lion uplink,
//! ⌈log2(N+1)⌉ Avg downlink, 1.6-bit TernGrad uplink, ⌈log2(2N+1)⌉
//! TernGrad downlink, 32-bit global channels) — the contract that keeps
//! the wire format honest as codecs and frames evolve.

use dlion::cluster::{run_sequential, TrainConfig};
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::tasks::quadratic::Quadratic;
use dlion::util::math::bits_for_count;

const D: usize = 4096;
const STEPS: usize = 4;

fn measured_bits_hp(name: &str, n: usize, hp: &StrategyHyper) -> (f64, f64) {
    let task = Quadratic::new(D, 5.0, 0.3, 9);
    let strat = by_name(name, hp).unwrap();
    let cfg = TrainConfig {
        steps: STEPS,
        batch_per_worker: 2,
        base_lr: 1e-3,
        eval_every: 0,
        seed: 3,
        ..Default::default()
    };
    let res = run_sequential(&task, strat.as_ref(), n, &cfg);
    let denom = (D * n * STEPS) as f64;
    (
        res.total_uplink() as f64 * 8.0 / denom,
        res.total_downlink() as f64 * 8.0 / denom,
    )
}

fn measured_bits(name: &str, n: usize) -> (f64, f64) {
    measured_bits_hp(name, n, &StrategyHyper::default())
}

fn assert_close(measured: f64, analytic: f64, ctx: &str) {
    // slack for the frame headers (tag / n / scale bytes)
    assert!(
        (measured - analytic).abs() / analytic < 0.02,
        "{ctx}: measured {measured:.4} bits/param vs analytic {analytic:.4}"
    );
}

#[test]
fn dlion_mavo_is_one_bit_each_way_for_odd_n() {
    for n in [1usize, 3, 5] {
        let (up, down) = measured_bits("d-lion-mavo", n);
        assert_close(up, 1.0, "mavo uplink");
        assert_close(down, 1.0, "mavo downlink (odd n)");
    }
}

#[test]
fn dlion_mavo_even_n_pays_the_ternary_tie_frame() {
    for n in [2usize, 4] {
        let (up, down) = measured_bits("d-lion-mavo", n);
        assert_close(up, 1.0, "mavo uplink");
        assert_close(down, 1.6, "mavo downlink (even n)");
    }
}

#[test]
fn dlion_avg_downlink_is_log_n_bits() {
    for n in [2usize, 4, 8] {
        let (up, down) = measured_bits("d-lion-avg", n);
        assert_close(up, 1.0, "avg uplink");
        assert_close(down, bits_for_count(n) as f64, "avg downlink");
    }
}

#[test]
fn signum_matches_dlion_rates() {
    let (up, down) = measured_bits("d-signum-mavo", 3);
    assert_close(up, 1.0, "signum uplink");
    assert_close(down, 1.0, "signum downlink");
    let (up, down) = measured_bits("d-signum-avg", 4);
    assert_close(up, 1.0, "signum-avg uplink");
    assert_close(down, 3.0, "signum-avg downlink"); // ceil(log2(5))
}

#[test]
fn global_channels_are_dense_32_bit() {
    for name in ["g-lion", "g-adamw", "g-sgd"] {
        let (up, down) = measured_bits(name, 2);
        assert_close(up, 32.0, "global uplink");
        assert_close(down, 32.0, "global downlink");
    }
}

#[test]
fn terngrad_rates_match_table1() {
    for n in [4usize, 8] {
        let (up, down) = measured_bits("terngrad", n);
        assert_close(up, 1.6, "terngrad uplink"); // 8/5 packed trits
        let expect = bits_for_count(2 * n) as f64; // ceil(log2(2n+1))
        assert_close(down, expect, "terngrad downlink");
    }
}

#[test]
fn graddrop_uplink_tracks_keep_fraction() {
    // keep 4%: 64·keep bits/param plus the 64-bit header.
    let (up, down) = measured_bits("graddrop", 4);
    let k = (0.04f64 * D as f64).ceil();
    let analytic = (64.0 + 64.0 * k) / D as f64;
    assert_close(up, analytic, "graddrop uplink");
    assert_close(down, 32.0, "graddrop downlink");
}

#[test]
fn dlion_ef_rides_the_same_one_bit_channels_as_mavo() {
    // Error feedback is worker-local: the wire must stay at D-Lion rates.
    for n in [1usize, 3, 5] {
        let (up, down) = measured_bits("d-lion-ef", n);
        assert_close(up, 1.0, "ef uplink");
        assert_close(down, 1.0, "ef downlink (odd n)");
    }
}

#[test]
fn msync_amortized_bits_account_for_the_momentum_frame() {
    // msync_every = 2 with STEPS = 4 fires exactly 2 sync rounds, so the
    // measured average equals the amortized model: 1 + 16/2 = 9 bits each
    // way on top of the odd-N MaVo base.
    let hp = StrategyHyper { msync_every: 2, ..Default::default() };
    let n = 3;
    let (up, down) = measured_bits_hp("d-lion-msync", n, &hp);
    assert_close(up, 9.0, "msync amortized uplink");
    assert_close(down, 9.0, "msync amortized downlink");
    // ...and the strategy's own model agrees with the wire.
    let strat = by_name("d-lion-msync", &hp).unwrap();
    assert_close(up, strat.uplink_bits_per_param(n), "msync model uplink");
    assert_close(down, strat.downlink_bits_per_param(n), "msync model downlink");
}

#[test]
fn dlion_local_amortized_bits_divide_by_the_window() {
    // d-lion-local(2) with STEPS = 4 holds exactly 2 sync rounds: the
    // measured average must equal the amortized model, 1/H bits each way
    // on the odd-N majority-vote channels.
    let hp = StrategyHyper::default();
    let n = 3;
    let (up, down) = measured_bits("d-lion-local(2)", n);
    assert_close(up, 0.5, "local(2) amortized uplink");
    assert_close(down, 0.5, "local(2) amortized downlink");
    let strat = by_name("d-lion-local(2)", &hp).unwrap();
    assert_close(up, strat.uplink_bits_per_param(n), "local model uplink");
    assert_close(down, strat.downlink_bits_per_param(n), "local model downlink");
}

#[test]
fn bandwidth_aware_selector_matches_its_amortized_model() {
    // Budget 33 against cheap d-lion-mavo (2 bits total, odd N) and rich
    // g-lion (64): the bucket alternates cheap/rich, so 4 steps hold
    // exactly two of each and the measurement equals the long-run model.
    let hp = StrategyHyper { link_budget: 33.0, ..Default::default() };
    let name = "bandwidth-aware(d-lion-mavo,g-lion)";
    let n = 3;
    let (up, down) = measured_bits_hp(name, n, &hp);
    assert_close(up, 16.5, "selector uplink (half sign, half dense)");
    assert_close(down, 16.5, "selector downlink");
    let strat = by_name(name, &hp).unwrap();
    assert_close(up, strat.uplink_bits_per_param(n), "selector model uplink");
    assert_close(down, strat.downlink_bits_per_param(n), "selector model downlink");
    // The measured total respects the configured budget (plus frame-header
    // slack): the "never exceeds the link budget" contract, on the wire.
    assert!(
        up + down <= 33.0 * 1.02,
        "selector overspent the link budget: {up} + {down} vs 33"
    );
}

#[test]
fn compact_sparse_uplink_is_40_bits_per_entry() {
    // delta-varint indices ride ~1 byte each at the 4% keep rate: 8-bit
    // index + 32-bit value = 40 bits/entry, vs 64 for the classic format
    // (regression for the ROADMAP compact-sparse item).
    let hp = StrategyHyper { compact_sparse: true, dgc_warmup_steps: 0, ..Default::default() };
    let k = (0.04f64 * D as f64).ceil();
    // headers: 8-bit tag + 96-bit (d, k, index_bytes) compact header
    let analytic = (104.0 + 40.0 * k) / D as f64;
    for name in ["graddrop", "dgc"] {
        let (up, down) = measured_bits_hp(name, 4, &hp);
        assert_close(up, analytic, name);
        assert_close(down, 32.0, name);
        // the strategy's analytic model uses the headerless 40·keep rate
        let strat = by_name(name, &hp).unwrap();
        assert_close(strat.uplink_bits_per_param(4), 40.0 * 0.04, name);
    }
}

/// Measured per-hop bits/param for a mixed assignment: run a chunked
/// hierarchical round loop and normalize each hop's payload bytes the
/// way its analytic model is stated — worker edge per worker, agg hop
/// per group.
fn measured_mixed_bits(
    name: &str,
    n: usize,
    group_size: usize,
    dim: usize,
    chunk_size: usize,
    hp: &StrategyHyper,
) -> (f64, f64, f64, f64) {
    use dlion::cluster::topology::Topology;
    let task = Quadratic::new(dim, 5.0, 0.3, 9);
    let strat = by_name(name, hp).unwrap();
    let cfg = TrainConfig {
        steps: STEPS,
        batch_per_worker: 2,
        base_lr: 1e-3,
        eval_every: 0,
        seed: 3,
        chunk_size,
        topology: Topology::Hierarchical { group_size },
        ..Default::default()
    };
    let res = run_sequential(&task, strat.as_ref(), n, &cfg);
    let ngroups = n.div_ceil(group_size);
    let worker_denom = (dim * n * STEPS) as f64;
    let group_denom = (dim * ngroups * STEPS) as f64;
    (
        res.total_uplink() as f64 * 8.0 / worker_denom,
        res.total_downlink() as f64 * 8.0 / worker_denom,
        res.total_agg_uplink() as f64 * 8.0 / group_denom,
        res.total_agg_downlink() as f64 * 8.0 / group_denom,
    )
}

#[test]
fn mixed_seven_eighths_sign_assignment_matches_the_weighted_model() {
    // 7/8 of the chunks ride 1-bit majority votes, 1/8 dense f32: with
    // D = 1600 and 200-element chunks the 8-slot cycle divides the
    // chunk count exactly, so the measured rate must equal the
    // chunk-share weighted model on *both* hops — worker edge and the
    // aggregator→root link (7/8 intavg vote partials + 1/8 dense sums).
    let hp = StrategyHyper::default();
    let name = "mixed(d-lion-mavo*7,g-lion)";
    let (n, g, dim, chunk) = (4usize, 2usize, 1600usize, 200usize);
    let (up, down, agg_up, agg_down) = measured_mixed_bits(name, n, g, dim, chunk, &hp);
    let up_model = (7.0 * 1.0 + 32.0) / 8.0; // 4.875
    let down_model = (7.0 * 1.6 + 32.0) / 8.0; // even N: ternary tie frames
    let partial_model = (7.0 * 2.0 + 32.0) / 8.0; // ⌈log2(3)⌉-bit votes + f32 sums
    assert_close(up, up_model, "mixed 7:1 uplink");
    assert_close(down, down_model, "mixed 7:1 downlink");
    assert_close(agg_up, partial_model, "mixed 7:1 agg-hop partials");
    assert_close(agg_down, down_model, "mixed 7:1 agg-hop broadcast");
    // ...and the strategy's own Table-1 model states these very rates
    // (up/partial blends are dyadic and exact; the 1.6-bit ternary term
    // gets an ulp of slack)
    let strat = by_name(name, &hp).unwrap();
    assert_eq!(strat.uplink_bits_per_param(n), up_model);
    assert!((strat.downlink_bits_per_param(n) - down_model).abs() < 1e-12);
    assert_eq!(strat.partial_bits_per_param(g), partial_model);
}

#[test]
fn mixed_half_and_half_assignment_matches_the_weighted_model() {
    // The 1:1 cycle alternates sign and dense chunks — the second
    // pinned assignment of the regression matrix.
    let hp = StrategyHyper::default();
    let name = "mixed(d-lion-mavo,g-lion)";
    let (n, g, dim, chunk) = (4usize, 2usize, 1600usize, 200usize);
    let (up, down, agg_up, agg_down) = measured_mixed_bits(name, n, g, dim, chunk, &hp);
    assert_close(up, (1.0 + 32.0) / 2.0, "mixed 1:1 uplink");
    assert_close(down, (1.6 + 32.0) / 2.0, "mixed 1:1 downlink");
    assert_close(agg_up, (2.0 + 32.0) / 2.0, "mixed 1:1 agg-hop partials");
    assert_close(agg_down, (1.6 + 32.0) / 2.0, "mixed 1:1 agg-hop broadcast");
    let strat = by_name(name, &hp).unwrap();
    assert_close(up, strat.uplink_bits_per_param(n), "mixed model uplink");
    assert_close(down, strat.downlink_bits_per_param(n), "mixed model downlink");
    assert_close(agg_up, strat.partial_bits_per_param(g), "mixed model partials");
}

#[test]
fn analytic_model_agrees_with_measurement_for_fixed_rate_strategies() {
    // The strategy's own Table-1 model (what the netsim bench projects
    // from) must agree with what actually crossed the wire.
    for (name, n) in [
        ("d-lion-mavo", 5usize),
        ("d-lion-avg", 4),
        ("d-signum-mavo", 3),
        ("g-lion", 2),
        ("terngrad", 4),
    ] {
        let hp = StrategyHyper::default();
        let strat = by_name(name, &hp).unwrap();
        let (up, down) = measured_bits(name, n);
        assert_close(up, strat.uplink_bits_per_param(n), name);
        assert_close(down, strat.downlink_bits_per_param(n), name);
    }
}
