//! TCP fault-path tests: mid-frame drops, read deadlines, the
//! reconnect/replay handshake, and hostile-peer hardening.
//!
//! The contract under test (see `comm::tcp`):
//! * a worker that dies mid-frame is a **named** error (`worker {i}:
//!   ...`) on the lockstep path and a dead-mark on the elastic path —
//!   never a hang in `read_exact`;
//! * a merely-late worker under a read deadline yields `None` for the
//!   round and its connection survives;
//! * a reconnecting worker presents `[id][applied_rounds]` and receives
//!   exactly the broadcasts it missed, oldest first, round-id checked;
//!   gaps beyond the replay ring are refused by name;
//! * truncated / garbage / oversized bytes on either end of the
//!   reconnect path produce errors, never panics or allocations;
//! * replayed frames are accounted under their own `CommStats` counter,
//!   never as a second broadcast;
//! * a reconnect-installed socket inherits the server's stored read
//!   deadline (a silent rejoiner can time out, not hang the gather);
//! * the replay ring depth is a knob (`hyper.replay_ring`), the
//!   worker's uplink in-flight cap and the server's write deadline
//!   bound both directions of a stalled pipe.

use dlion::comm::tcp::{bind_loopback, TcpServer, TcpWorker, DEFAULT_REPLAY_RING};
use dlion::comm::{CommStats, ServerTransport, WorkerTransport};
use dlion::util::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

/// Loopback FIN delivery is immediate but not synchronous with `drop`;
/// a short pause makes EOF-vs-timeout checks deterministic.
fn settle() {
    thread::sleep(Duration::from_millis(50));
}

#[test]
fn mid_frame_drop_is_a_named_error_not_a_hang() {
    let (port, listener) = bind_loopback().unwrap();
    let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
    s.write_all(&0u32.to_le_bytes()).unwrap(); // handshake: id 0
    s.write_all(&0u32.to_le_bytes()).unwrap(); // handshake: 0 applied
    s.write_all(&64u32.to_le_bytes()).unwrap(); // frame claims 64 bytes...
    s.write_all(&[0xAB; 10]).unwrap(); // ...delivers 10
    drop(s);
    let mut server =
        TcpServer::accept(&listener, 1, CommStats::new(), DEFAULT_REPLAY_RING).unwrap();
    let err = server.gather().unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    let msg = err.to_string();
    assert!(msg.contains("worker 0"), "error must name the worker: {msg}");
}

#[test]
fn deadline_gather_keeps_stragglers_and_buries_the_dead() {
    let stats = CommStats::new();
    let (port, listener) = bind_loopback().unwrap();
    let mut w0 = TcpWorker::connect(port, 0, stats.clone()).unwrap();
    let w1 = TcpWorker::connect(port, 1, stats.clone()).unwrap();
    let mut server = TcpServer::accept(&listener, 2, stats, DEFAULT_REPLAY_RING).unwrap();

    // Round 1: worker 1 is merely late — `None` for the round, but the
    // connection must survive the deadline.
    w0.send(vec![1u8, 0xAA]).unwrap();
    let msgs = server.gather_quorum(Some(Duration::from_millis(150))).unwrap();
    assert_eq!(msgs[0].as_deref(), Some(&[1u8, 0xAA][..]));
    assert_eq!(msgs[1], None);
    assert!(server.is_live(1), "a straggler is not dead");
    assert_eq!(server.live_workers(), 2);

    // Round 2: worker 1 hangs up. EOF inside the deadline window marks
    // the slot dead instead of timing out round after round.
    drop(w1);
    settle();
    w0.send(vec![1u8, 0xBB]).unwrap();
    let msgs = server.gather_quorum(Some(Duration::from_millis(150))).unwrap();
    assert_eq!(msgs[0].as_deref(), Some(&[1u8, 0xBB][..]));
    assert_eq!(msgs[1], None);
    assert!(!server.is_live(1), "EOF must mark the worker dead");
    assert_eq!(server.live_workers(), 1);

    // Dead slots answer immediately — no deadline burned on them.
    w0.send(vec![1u8, 0xCC]).unwrap();
    let msgs = server.gather_quorum(Some(Duration::from_millis(150))).unwrap();
    assert_eq!(msgs[1], None);
    // ...and the lockstep gather refuses by name rather than hanging.
    w0.send(vec![1u8, 0xDD]).unwrap();
    let err = server.gather().unwrap_err();
    assert!(err.to_string().contains("worker 1"), "unnamed: {err}");
}

#[test]
fn reconnect_replays_exactly_the_missed_broadcasts() {
    let stats = CommStats::new();
    let (port, listener) = bind_loopback().unwrap();
    let mut w0 = TcpWorker::connect(port, 0, stats.clone()).unwrap();
    let mut w1 = TcpWorker::connect(port, 1, stats.clone()).unwrap();
    let mut server =
        TcpServer::accept(&listener, 2, stats.clone(), DEFAULT_REPLAY_RING).unwrap();
    let (b1, b2, b3, b4) = ([1u8, 11], [1u8, 22], [1u8, 33], [1u8, 44]);

    // Round 1: full lockstep round; worker 1 applies broadcast b1.
    w0.send(vec![1u8, 0]).unwrap();
    w1.send(vec![1u8, 1]).unwrap();
    server.gather().unwrap();
    server.broadcast(&b1).unwrap();
    assert_eq!(&w0.recv().unwrap()[..], &b1[..]);
    assert_eq!(&w1.recv().unwrap()[..], &b1[..]);
    let applied = w1.rounds_received();
    assert_eq!(applied, 1);

    // Rounds 2-3: worker 1 is gone; the survivors keep moving and the
    // ring accumulates the broadcasts it missed.
    drop(w1);
    settle();
    for b in [&b2, &b3] {
        w0.send(vec![1u8, 0]).unwrap();
        let msgs = server.gather_quorum(Some(Duration::from_millis(150))).unwrap();
        assert!(msgs[0].is_some() && msgs[1].is_none());
        server.broadcast(b).unwrap();
        assert_eq!(&w0.recv().unwrap()[..], &b[..]);
    }
    assert!(!server.is_live(1));
    assert_eq!(server.round(), 3);

    // Reconnect: worker 1 presents [id=1][applied=1] and must get b2
    // then b3 — exactly the gap, oldest first, nothing else.
    let client = {
        let stats = stats.clone();
        thread::spawn(move || TcpWorker::reconnect(port, 1, applied, stats, DEFAULT_REPLAY_RING))
    };
    let rejoined = server.accept_reconnect(&listener).unwrap();
    assert_eq!(rejoined, 1);
    assert!(server.is_live(1));
    let (mut w1, replayed) = client.join().unwrap().unwrap();
    assert_eq!(replayed.len(), 2, "missed exactly two broadcasts");
    assert_eq!(&replayed[0][..], &b2[..]);
    assert_eq!(&replayed[1][..], &b3[..]);
    assert_eq!(w1.rounds_received(), 3, "caught up to the cluster round");

    // The rejoined replica participates in a normal lockstep round.
    server.set_read_deadline(None).unwrap();
    w0.send(vec![1u8, 0]).unwrap();
    w1.send(vec![1u8, 1]).unwrap();
    let msgs = server.gather().unwrap();
    assert_eq!(msgs.len(), 2);
    server.broadcast(&b4).unwrap();
    assert_eq!(&w0.recv().unwrap()[..], &b4[..]);
    assert_eq!(&w1.recv().unwrap()[..], &b4[..]);
    assert_eq!(w1.rounds_received(), 4);
}

#[test]
fn reconnect_gap_beyond_the_ring_is_refused_by_name() {
    let stats = CommStats::new();
    let (port, listener) = bind_loopback().unwrap();
    let mut w0 = TcpWorker::connect(port, 0, stats.clone()).unwrap();
    let mut server =
        TcpServer::accept(&listener, 1, stats.clone(), DEFAULT_REPLAY_RING).unwrap();
    // 10 broadcast rounds > DEFAULT_REPLAY_RING (8): a worker claiming 0 applied
    // rounds can no longer be caught up from the ring.
    for k in 0..10u8 {
        w0.send(vec![1u8, k]).unwrap();
        server.gather().unwrap();
        server.broadcast(&[1u8, k]).unwrap();
        w0.recv().unwrap();
    }
    server.disconnect(0);
    let client = {
        let stats = stats.clone();
        thread::spawn(move || TcpWorker::reconnect(port, 0, 0, stats, DEFAULT_REPLAY_RING))
    };
    let err = server.accept_reconnect(&listener).unwrap_err();
    assert!(err.to_string().contains("replay ring"), "unnamed: {err}");
    assert!(!server.is_live(0), "a refused rejoin must not fill the slot");
    // The client sees the hangup as a named reconnect failure, not a
    // hang or a half-initialized worker.
    let client_err = client.join().unwrap().err().expect("client must fail too");
    assert!(
        client_err.to_string().contains("reconnect replay header"),
        "unnamed: {client_err}"
    );
}

#[test]
fn reconnect_from_the_future_is_refused_by_name() {
    let stats = CommStats::new();
    let (port, listener) = bind_loopback().unwrap();
    let mut w0 = TcpWorker::connect(port, 0, stats.clone()).unwrap();
    let mut server =
        TcpServer::accept(&listener, 1, stats.clone(), DEFAULT_REPLAY_RING).unwrap();
    w0.send(vec![1u8, 0]).unwrap();
    server.gather().unwrap();
    server.broadcast(&[1u8, 9]).unwrap();
    w0.recv().unwrap();
    server.disconnect(0);
    let client =
        thread::spawn(move || TcpWorker::reconnect(port, 0, 99, stats, DEFAULT_REPLAY_RING));
    let err = server.accept_reconnect(&listener).unwrap_err();
    assert!(err.to_string().contains("applied rounds"), "unnamed: {err}");
    let _ = client.join().unwrap(); // client errors too (server hung up)
}

#[test]
fn garbage_handshakes_on_the_reconnect_path_never_panic() {
    // Seeded fuzz over the handshake reader: truncated prefixes, random
    // ids, future round claims. Every case must be an `Err` (both
    // slots are live, so even a well-formed handshake is refused), the
    // live connections must be untouched, and nothing may panic.
    let stats = CommStats::new();
    let (port, listener) = bind_loopback().unwrap();
    let mut w0 = TcpWorker::connect(port, 0, stats.clone()).unwrap();
    let mut w1 = TcpWorker::connect(port, 1, stats.clone()).unwrap();
    let mut server = TcpServer::accept(&listener, 2, stats, DEFAULT_REPLAY_RING).unwrap();

    let mut rng = Rng::new(0xF417);
    for case in 0..24usize {
        let len = rng.below(9); // 0..=8 bytes of noise
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
        s.write_all(&bytes).unwrap();
        drop(s); // EOF follows whatever arrived
        let err = server.accept_reconnect(&listener).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("handshake")
                || msg.contains("bad worker id")
                || msg.contains("still live")
                || msg.contains("applied rounds"),
            "case {case} ({len} bytes): unnamed error: {msg}"
        );
    }
    // Targeted probes: a live id, a future claim on a live id, an
    // out-of-range id — all named refusals.
    for (id, applied, needle) in
        [(0u32, 0u32, "still live"), (1, 7, "still live"), (5, 0, "bad worker id")]
    {
        let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
        s.write_all(&id.to_le_bytes()).unwrap();
        s.write_all(&applied.to_le_bytes()).unwrap();
        drop(s);
        let err = server.accept_reconnect(&listener).unwrap_err();
        assert!(err.to_string().contains(needle), "id {id}: {err}");
    }

    // The fuzz storm must not have perturbed the real cluster.
    assert_eq!(server.live_workers(), 2);
    w0.send(vec![1u8, 0]).unwrap();
    w1.send(vec![1u8, 1]).unwrap();
    assert_eq!(server.gather().unwrap().len(), 2);
    server.broadcast(&[1u8, 5]).unwrap();
    assert_eq!(&w0.recv().unwrap()[..], &[1u8, 5][..]);
    assert_eq!(&w1.recv().unwrap()[..], &[1u8, 5][..]);
}

#[test]
fn client_rejects_hostile_replay_headers() {
    let (port, listener) = bind_loopback().unwrap();

    // A server claiming more replay frames than any ring can hold: the
    // client refuses before allocating or reading a single frame.
    let client = thread::spawn(move || {
        TcpWorker::reconnect(port, 0, 0, CommStats::new(), DEFAULT_REPLAY_RING)
    });
    let (mut s, _) = listener.accept().unwrap();
    let mut hs = [0u8; 8];
    s.read_exact(&mut hs).unwrap();
    assert_eq!(hs, [0, 0, 0, 0, 0, 0, 0, 0]);
    s.write_all(&9999u32.to_le_bytes()).unwrap();
    let err = client.join().unwrap().err().expect("oversized count must fail");
    assert!(err.to_string().contains("replay frames"), "unnamed: {err}");

    // A replay frame with a 4 GB length prefix: the frame reader's
    // budget clamp fires on the reconnect path too.
    let client = thread::spawn(move || {
        TcpWorker::reconnect(port, 0, 3, CommStats::new(), DEFAULT_REPLAY_RING)
    });
    let (mut s, _) = listener.accept().unwrap();
    s.read_exact(&mut hs).unwrap();
    assert_eq!(hs, [0, 0, 0, 0, 3, 0, 0, 0], "handshake carries [id][applied]");
    s.write_all(&1u32.to_le_bytes()).unwrap(); // one replay frame...
    s.write_all(&u32::MAX.to_le_bytes()).unwrap(); // ...claiming 4 GB
    let err = client.join().unwrap().err().expect("oversized frame must fail");
    assert!(err.to_string().contains("MAX_FRAME_BYTES"), "unnamed: {err}");

    // A truncated count header (server dies mid-reply) is a named
    // error, not a hang.
    let client = thread::spawn(move || {
        TcpWorker::reconnect(port, 0, 0, CommStats::new(), DEFAULT_REPLAY_RING)
    });
    let (mut s, _) = listener.accept().unwrap();
    s.read_exact(&mut hs).unwrap();
    s.write_all(&[1u8, 2]).unwrap(); // half a count, then hang up
    drop(s);
    let err = client.join().unwrap().err().expect("truncated count must fail");
    assert!(err.to_string().contains("reconnect replay header"), "unnamed: {err}");
}

#[test]
fn reconnect_install_inherits_the_read_deadline() {
    // Regression: the server stores its read deadline and must apply it
    // to sockets installed by `accept_reconnect`. Before the fix, a
    // rejoined worker that went silent would hang a lockstep gather
    // forever — its fresh socket never got the timeout.
    let stats = CommStats::new();
    let (port, listener) = bind_loopback().unwrap();
    let mut w0 = TcpWorker::connect(port, 0, stats.clone()).unwrap();
    let mut w1 = TcpWorker::connect(port, 1, stats.clone()).unwrap();
    let mut server =
        TcpServer::accept(&listener, 2, stats.clone(), DEFAULT_REPLAY_RING).unwrap();
    server.set_read_deadline(Some(Duration::from_millis(150))).unwrap();

    // One full round so the rejoiner has an applied count.
    w0.send(vec![1u8, 0]).unwrap();
    w1.send(vec![1u8, 1]).unwrap();
    server.gather().unwrap();
    server.broadcast(&[1u8, 7]).unwrap();
    w0.recv().unwrap();
    let applied = {
        w1.recv().unwrap();
        w1.rounds_received()
    };
    drop(w1);
    settle();

    // Rejoin with nothing missed: zero frames replayed, socket installed.
    let client = {
        let stats = stats.clone();
        thread::spawn(move || TcpWorker::reconnect(port, 1, applied, stats, DEFAULT_REPLAY_RING))
    };
    assert_eq!(server.accept_reconnect(&listener).unwrap(), 1);
    let (_w1, replayed) = client.join().unwrap().unwrap();
    assert!(replayed.is_empty());

    // The rejoined worker stays silent; the lockstep gather must time
    // out by name through the installed deadline instead of hanging.
    w0.send(vec![1u8, 2]).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    thread::spawn(move || {
        let _ = tx.send(server.gather().map(|_| ()).map_err(|e| e.to_string()));
    });
    let res = rx
        .recv_timeout(Duration::from_secs(2))
        .expect("gather must hit the installed deadline, not hang");
    let msg = res.err().expect("a silent rejoined worker must be a timeout error");
    assert!(msg.contains("worker 1"), "unnamed: {msg}");
}

#[test]
fn replay_traffic_is_counted_separately_from_broadcasts() {
    // Replayed frames are real wire traffic, but not a second logical
    // broadcast: they land on `CommStats::replay`, and the downlink
    // round accounting must not move during a reconnect.
    let stats = CommStats::new();
    let (port, listener) = bind_loopback().unwrap();
    let mut w0 = TcpWorker::connect(port, 0, stats.clone()).unwrap();
    let mut w1 = TcpWorker::connect(port, 1, stats.clone()).unwrap();
    let mut server =
        TcpServer::accept(&listener, 2, stats.clone(), DEFAULT_REPLAY_RING).unwrap();

    // Round 1 in lockstep, then worker 1 misses rounds 2-3.
    w0.send(vec![1u8, 0]).unwrap();
    w1.send(vec![1u8, 1]).unwrap();
    server.gather().unwrap();
    server.broadcast(&[1u8, 11]).unwrap();
    w0.recv().unwrap();
    w1.recv().unwrap();
    drop(w1);
    settle();
    for b in [[1u8, 22], [1u8, 33]] {
        w0.send(vec![1u8, 0]).unwrap();
        server.gather_quorum(Some(Duration::from_millis(150))).unwrap();
        server.broadcast(&b).unwrap();
        w0.recv().unwrap();
    }
    assert_eq!(stats.replay(), 0, "no replay traffic before any reconnect");
    assert_eq!(stats.replay_msg_count(), 0);
    let down_before = stats.downlink();

    // Reconnect replays the two missed 2-byte broadcasts.
    let client = {
        let stats = stats.clone();
        thread::spawn(move || TcpWorker::reconnect(port, 1, 1, stats, DEFAULT_REPLAY_RING))
    };
    assert_eq!(server.accept_reconnect(&listener).unwrap(), 1);
    let (_w1, replayed) = client.join().unwrap().unwrap();
    assert_eq!(replayed.len(), 2);
    assert_eq!(stats.replay(), 4, "two 2-byte frames replayed");
    assert_eq!(stats.replay_msg_count(), 2);
    assert_eq!(stats.downlink(), down_before, "replay is not a second broadcast");
}

#[test]
fn replay_ring_depth_is_a_knob_on_both_ends() {
    // Server side: a ring of 2 refuses a 3-round gap and serves a
    // 2-round one.
    let stats = CommStats::new();
    let (port, listener) = bind_loopback().unwrap();
    let mut w0 = TcpWorker::connect(port, 0, stats.clone()).unwrap();
    let mut server = TcpServer::accept(&listener, 1, stats.clone(), 2).unwrap();
    for k in 0..3u8 {
        w0.send(vec![1u8, k]).unwrap();
        server.gather().unwrap();
        server.broadcast(&[1u8, k]).unwrap();
        w0.recv().unwrap();
    }
    server.disconnect(0);
    let client = {
        let stats = stats.clone();
        thread::spawn(move || TcpWorker::reconnect(port, 0, 0, stats, 2))
    };
    let err = server.accept_reconnect(&listener).unwrap_err();
    assert!(err.to_string().contains("replay ring"), "unnamed: {err}");
    let _ = client.join().unwrap(); // client fails too (server hung up)

    let client = {
        let stats = stats.clone();
        thread::spawn(move || TcpWorker::reconnect(port, 0, 1, stats, 2))
    };
    assert_eq!(server.accept_reconnect(&listener).unwrap(), 0);
    let (_w0, replayed) = client.join().unwrap().unwrap();
    assert_eq!(replayed.len(), 2, "a gap of exactly the ring depth replays");
    assert_eq!(&replayed[0][..], &[1u8, 1][..]);
    assert_eq!(&replayed[1][..], &[1u8, 2][..]);

    // Client side: the hostile-count clamp scales with the ring the
    // client was configured for.
    let (port2, listener2) = bind_loopback().unwrap();
    let client =
        thread::spawn(move || TcpWorker::reconnect(port2, 0, 0, CommStats::new(), 2));
    let (mut s, _) = listener2.accept().unwrap();
    let mut hs = [0u8; 8];
    s.read_exact(&mut hs).unwrap();
    s.write_all(&3u32.to_le_bytes()).unwrap(); // claims 3 > ring 2
    let err = client.join().unwrap().err().expect("count beyond the ring must fail");
    assert!(err.to_string().contains("ring capacity 2"), "unnamed: {err}");
}

#[test]
fn uplink_backpressure_caps_frames_in_flight() {
    let stats = CommStats::new();
    let (port, listener) = bind_loopback().unwrap();
    let mut w = TcpWorker::connect(port, 0, stats.clone()).unwrap();
    let mut server = TcpServer::accept(&listener, 1, stats, DEFAULT_REPLAY_RING).unwrap();
    w.set_max_in_flight(2);
    w.send(vec![1u8, 1]).unwrap();
    w.send(vec![1u8, 2]).unwrap();
    let err = w.send(vec![1u8, 3]).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::WouldBlock);
    let msg = err.to_string();
    assert!(msg.contains("backpressure") && msg.contains("worker 0"), "unnamed: {msg}");

    // Applying a downlink frees a slot and the send goes through.
    let msgs = server.gather().unwrap();
    assert_eq!(&msgs[0][..], &[1u8, 1][..]);
    server.broadcast(&[1u8, 9]).unwrap();
    w.recv().unwrap();
    w.send(vec![1u8, 3]).unwrap();
}

#[test]
fn write_deadline_buries_a_stalled_receiver() {
    // A worker that stops draining its downlink fills the socket
    // buffers; with a write deadline the broadcast dead-marks it
    // instead of blocking the whole cluster behind one slow pipe.
    let stats = CommStats::new();
    let (port, listener) = bind_loopback().unwrap();
    let _w = TcpWorker::connect(port, 0, stats.clone()).unwrap(); // never reads
    let mut server = TcpServer::accept(&listener, 1, stats, DEFAULT_REPLAY_RING).unwrap();
    server.set_write_deadline(Some(Duration::from_millis(50))).unwrap();
    let big = vec![1u8; 8 << 20];
    for _ in 0..4 {
        server.broadcast(&big).unwrap();
        if !server.is_live(0) {
            break;
        }
    }
    assert!(!server.is_live(0), "a stalled receiver must be dead-marked, not block");
}
