//! Topology parity: the acceptance contract of the topology-aware round
//! engine.
//!
//! * Degenerate configs reproduce today's flat star **bit-for-bit**:
//!   `Hierarchical { group_size >= nworkers }` (one group) and
//!   `d-lion-local(1)` must match flat every-step `d-lion-mavo` in
//!   parameters and in the per-step worker-edge byte history.
//! * For the sign-vote family *any* grouping is trajectory-identical
//!   (integer vote partials regroup exactly); relayed codecs are exact
//!   for any grouping too.
//! * `run_sequential` and `run_threaded` agree bit-exactly — params and
//!   the full per-hop byte history — for a hierarchical topology with
//!   ≥ 2 groups and for `d-lion-local(4)`.

use dlion::cluster::topology::Topology;
use dlion::cluster::{run_sequential, run_threaded, TrainConfig};
use dlion::comm::intavg;
use dlion::optim::dist::{by_name, StrategyHyper};
use dlion::tasks::quadratic::Quadratic;
use dlion::tasks::GradTask;
use std::sync::Arc;

const D: usize = 96;

fn cfg(steps: usize, topology: Topology) -> TrainConfig {
    TrainConfig {
        steps,
        batch_per_worker: 4,
        base_lr: 0.01,
        eval_every: 0,
        seed: 13,
        check_replicas: true,
        topology,
        ..Default::default()
    }
}

fn task() -> Quadratic {
    Quadratic::new(D, 6.0, 0.4, 17)
}

fn task_arc() -> Arc<dyn GradTask + Send + Sync> {
    Arc::new(task())
}

#[test]
fn chunked_wire_is_bit_exact_and_payload_identical() {
    // Acceptance contract of the chunked redesign: for the native
    // families, any chunk_size yields parameters and a per-step payload
    // byte history identical to the monolithic path. chunk_size 1 and 7
    // exercise codec alignment (the sign family rounds both up to 40);
    // D and D+3 collapse to the single-chunk plan.
    let n = 4;
    let hp = StrategyHyper::default();
    for name in ["d-lion-mavo", "g-lion", "dgc"] {
        let strat = by_name(name, &hp).unwrap();
        let mono = run_sequential(&task(), strat.as_ref(), n, &cfg(25, Topology::Star));
        for chunk_size in [1usize, 7, D, D + 3] {
            let c = TrainConfig { chunk_size, ..cfg(25, Topology::Star) };
            let res = run_sequential(&task(), strat.as_ref(), n, &c);
            assert_eq!(
                res.final_params, mono.final_params,
                "{name}: chunk_size={chunk_size} changed the trajectory"
            );
            assert_eq!(res.total_uplink(), mono.total_uplink(), "{name} cs={chunk_size}");
            assert_eq!(res.total_downlink(), mono.total_downlink(), "{name} cs={chunk_size}");
            for (a, b) in mono.history.iter().zip(&res.history) {
                assert_eq!(
                    (a.uplink_bytes, a.downlink_bytes),
                    (b.uplink_bytes, b.downlink_bytes),
                    "{name} cs={chunk_size} step {}: per-step payload bytes moved",
                    a.step
                );
            }
        }
    }
}

#[test]
fn chunked_hierarchy_threaded_matches_sequential() {
    // Chunked frames over a two-group tree, both drivers: params, the
    // full per-hop byte history, and the transport counters must agree
    // — and match the monolithic hierarchical run.
    let n = 4;
    let topo = Topology::Hierarchical { group_size: 2 };
    let hp = StrategyHyper::default();
    let strat = by_name("d-lion-mavo", &hp).unwrap();
    let mono = run_sequential(&task(), strat.as_ref(), n, &cfg(30, topo));
    let c = TrainConfig { chunk_size: 7, ..cfg(30, topo) };
    let seq = run_sequential(&task(), strat.as_ref(), n, &c);
    assert_eq!(seq.final_params, mono.final_params, "chunking changed the hier trajectory");
    assert_eq!(seq.total_agg_uplink(), mono.total_agg_uplink(), "agg-hop payload moved");
    assert_eq!(seq.total_agg_downlink(), mono.total_agg_downlink());
    let (thr, stats) = run_threaded(task_arc(), strat.as_ref(), n, &c);
    assert_eq!(seq.final_params, thr.final_params);
    for (s, t) in seq.history.iter().zip(&thr.history) {
        assert_eq!(
            (s.uplink_bytes, s.downlink_bytes, s.agg_uplink_bytes, s.agg_downlink_bytes),
            (t.uplink_bytes, t.downlink_bytes, t.agg_uplink_bytes, t.agg_downlink_bytes),
            "step {}",
            s.step
        );
    }
    assert_eq!(stats.uplink(), seq.total_uplink());
    assert_eq!(stats.downlink(), seq.total_downlink());
    assert_eq!(stats.agg_uplink(), seq.total_agg_uplink());
    // hierarchical message counts are observable end-to-end: 2 groups ×
    // 30 sync rounds on each aggregator hop
    assert_eq!(stats.agg_uplink_msg_count(), 60);
    assert_eq!(stats.agg_downlink_msg_count(), 60);
    assert_eq!(seq.total_agg_uplink_msgs(), 60);
    assert_eq!(seq.total_agg_downlink_msgs(), 60);
}

#[test]
fn mixed_same_arm_everywhere_is_bit_exact_with_the_plain_arm() {
    // Acceptance contract of the mixed-wire selector: mixed(a,a) puts
    // the same arm on every chunk and every link, so it must reproduce
    // plain `a` bit-for-bit — parameters and the full per-hop payload
    // byte history — for every (chunk_size, topology, driver) cell.
    let n = 4;
    let hp = StrategyHyper::default();
    for arm in ["d-lion-mavo", "g-lion", "dgc"] {
        let plain = by_name(arm, &hp).unwrap();
        let mixed = by_name(&format!("mixed({arm},{arm})"), &hp).unwrap();
        assert_eq!(mixed.name(), format!("mixed({arm},{arm})"));
        for topo in [Topology::Star, Topology::Hierarchical { group_size: 4 }] {
            for chunk_size in [1usize, 7, D, D + 3] {
                let ctx = format!("mixed({arm},{arm}) cs={chunk_size} topo={topo}");
                let c = TrainConfig { chunk_size, ..cfg(20, topo) };
                let a = run_sequential(&task(), plain.as_ref(), n, &c);
                let b = run_sequential(&task(), mixed.as_ref(), n, &c);
                assert_eq!(a.final_params, b.final_params, "{ctx}: trajectory moved");
                assert_eq!(a.history.len(), b.history.len());
                for (x, y) in a.history.iter().zip(&b.history) {
                    assert_eq!(
                        (x.uplink_bytes, x.downlink_bytes, x.agg_uplink_bytes, x.agg_downlink_bytes),
                        (y.uplink_bytes, y.downlink_bytes, y.agg_uplink_bytes, y.agg_downlink_bytes),
                        "{ctx} step {}: per-hop payload bytes moved",
                        x.step
                    );
                }
                // threaded driver: same params, and the transport
                // counters equal the sequential payload accounting
                let (thr, stats) = run_threaded(task_arc(), mixed.as_ref(), n, &c);
                assert_eq!(a.final_params, thr.final_params, "{ctx}: threaded diverged");
                assert_eq!(stats.uplink(), a.total_uplink(), "{ctx}: uplink counter");
                assert_eq!(stats.downlink(), a.total_downlink(), "{ctx}: downlink counter");
                assert_eq!(stats.agg_uplink(), a.total_agg_uplink(), "{ctx}: agg counter");
            }
        }
    }
}

#[test]
fn heterogeneous_mixed_round_runs_end_to_end_with_per_hop_accounting() {
    // Genuinely heterogeneous wires: 1-bit sign votes and dense f32
    // frames in the same round, with *different arms on the agg→root
    // hop* (intavg vote partials next to tag-14 dense sums). Both
    // drivers agree bit-exactly, and the per-hop payload bytes match
    // the weighted analytic model exactly (D = 240 splits into 6
    // 40-element chunks; the 1:1 cycle gives each arm exactly half).
    let n = 4;
    let hp = StrategyHyper::default();
    let strat = by_name("mixed(d-lion-mavo,g-lion)", &hp).unwrap();
    let topo = Topology::Hierarchical { group_size: 2 };
    let d = 240;
    let steps = 24;
    let task = Quadratic::new(d, 6.0, 0.4, 17);
    let c = TrainConfig { chunk_size: 40, ..cfg(steps, topo) };
    let seq = run_sequential(&task, strat.as_ref(), n, &c);
    let task_arc: Arc<dyn GradTask + Send + Sync> = Arc::new(Quadratic::new(d, 6.0, 0.4, 17));
    let (thr, stats) = run_threaded(task_arc, strat.as_ref(), n, &c);
    assert_eq!(seq.final_params, thr.final_params, "drivers diverged on mixed wires");
    for (s, t) in seq.history.iter().zip(&thr.history) {
        assert_eq!(
            (s.uplink_bytes, s.downlink_bytes, s.agg_uplink_bytes, s.agg_downlink_bytes),
            (t.uplink_bytes, t.downlink_bytes, t.agg_uplink_bytes, t.agg_downlink_bytes),
            "step {}",
            s.step
        );
    }
    assert_eq!(stats.uplink(), seq.total_uplink());
    assert_eq!(stats.agg_uplink(), seq.total_agg_uplink());
    // exact per-hop payload bytes per round, straight from the frame
    // layouts (3 sign chunks + 3 dense chunks of 40 params each):
    let sign_up = 1 + 3 * 5; // one sign head + 3×40 bits
    let dense = 1 + 3 * 160; // one dense head + 3×40 f32
    let per_worker_up = (sign_up + dense) as u64;
    assert_eq!(seq.history[0].uplink_bytes, per_worker_up * n as u64, "uplink payload");
    // even N: majority-vote downlink pays the 1.6-bit ternary frame
    let tern_down = 1 + 3 * 8; // one tern head + 3×40 trits
    let per_worker_down = (tern_down + dense) as u64;
    assert_eq!(seq.history[0].downlink_bytes, per_worker_down * n as u64, "downlink payload");
    // agg hop, per group: 3 intavg vote partials (2 bits/param for
    // g = 2) + 3 dense f32 sums, heads charged once per tag
    let intavg_part = 3 + 3 * 10; // [3][n:u16] head + 3×40×2 bits
    let dense_part = 3 + 3 * 160; // [14][n:u16] head + 3×40 f32
    let per_group_up = (intavg_part + dense_part) as u64;
    assert_eq!(seq.history[0].agg_uplink_bytes, per_group_up * 2, "agg-hop partials");
    assert_eq!(seq.history[0].agg_downlink_bytes, per_worker_down * 2, "agg-hop broadcast");
}

#[test]
fn every_strategy_trains_under_a_configured_chunk_size() {
    // The full registry keeps working under any chunk_size: native
    // families chunk, everything else collapses to a single-chunk plan.
    // check_replicas (on in cfg()) pins the replicated-param invariant.
    let n = 4;
    let hp = StrategyHyper::default();
    for &name in dlion::optim::dist::ALL_STRATEGIES
        .iter()
        .chain(dlion::optim::dist::EXTENSION_STRATEGIES.iter())
    {
        let strat = by_name(name, &hp).unwrap();
        let c = TrainConfig { chunk_size: 5, ..cfg(12, Topology::Star) };
        let res = run_sequential(&task(), strat.as_ref(), n, &c);
        assert!(res.total_uplink() > 0, "{name}: no uplink bytes under chunking");
        assert!(res.total_downlink() > 0, "{name}: no downlink bytes under chunking");
    }
}

#[test]
fn one_group_hierarchy_is_bitwise_flat_star() {
    let n = 4;
    let hp = StrategyHyper::default();
    let strat = by_name("d-lion-mavo", &hp).unwrap();
    let flat = run_sequential(&task(), strat.as_ref(), n, &cfg(30, Topology::Star));
    let hier = run_sequential(
        &task(),
        strat.as_ref(),
        n,
        &cfg(30, Topology::Hierarchical { group_size: n }),
    );
    assert_eq!(flat.final_params, hier.final_params, "one group must not change the math");
    for (f, h) in flat.history.iter().zip(&hier.history) {
        assert_eq!(f.uplink_bytes, h.uplink_bytes, "step {} worker-edge uplink", f.step);
        assert_eq!(f.downlink_bytes, h.downlink_bytes, "step {} worker-edge downlink", f.step);
        // the star has no aggregator hop; the one-group tree pays one
        // intavg vote partial up and one broadcast copy down
        assert_eq!(f.agg_uplink_bytes, 0);
        assert_eq!(h.agg_uplink_bytes, (3 + intavg::packed_len(D, n)) as u64);
        assert_eq!(h.agg_downlink_bytes, f.downlink_bytes / n as u64);
    }
}

#[test]
fn vote_partials_keep_any_grouping_on_the_flat_trajectory() {
    let n = 6;
    let hp = StrategyHyper::default();
    for name in ["d-lion-mavo", "d-lion-avg", "d-signum-mavo"] {
        let strat = by_name(name, &hp).unwrap();
        let flat = run_sequential(&task(), strat.as_ref(), n, &cfg(25, Topology::Star));
        for gs in [1usize, 2, 3, 4] {
            let hier = run_sequential(
                &task(),
                strat.as_ref(),
                n,
                &cfg(25, Topology::Hierarchical { group_size: gs }),
            );
            assert_eq!(
                flat.final_params, hier.final_params,
                "{name}: group_size={gs} changed the trajectory"
            );
        }
    }
}

#[test]
fn relayed_and_dense_sum_partials_are_exact_end_to_end() {
    let n = 6;
    let hp = StrategyHyper::default();
    // terngrad relays (no mergeable partial): exact for any grouping
    let strat = by_name("terngrad", &hp).unwrap();
    let flat = run_sequential(&task(), strat.as_ref(), n, &cfg(20, Topology::Star));
    let hier = run_sequential(
        &task(),
        strat.as_ref(),
        n,
        &cfg(20, Topology::Hierarchical { group_size: 2 }),
    );
    assert_eq!(flat.final_params, hier.final_params, "relay partials must be exact");
    // relaying g members costs more than the members themselves (length
    // headers) — the honest price of a codec with no partial aggregate
    assert!(hier.total_agg_uplink() > hier.total_uplink());
    // g-lion's dense-sum partial: one full group is bitwise the flat sum
    let strat = by_name("g-lion", &hp).unwrap();
    let flat = run_sequential(&task(), strat.as_ref(), n, &cfg(20, Topology::Star));
    let hier = run_sequential(
        &task(),
        strat.as_ref(),
        n,
        &cfg(20, Topology::Hierarchical { group_size: n }),
    );
    assert_eq!(flat.final_params, hier.final_params, "dense-sum partial must be exact");
    // ...and the root link carries one 32-bit frame per group, not per
    // worker: 6 dense uplinks on the worker edge, 1 dense sum above
    let per_round_worker_edge = flat.history[0].uplink_bytes;
    let per_round_root_link = hier.history[0].agg_uplink_bytes;
    assert!(per_round_root_link * 5 < per_round_worker_edge);
}

#[test]
fn local_steps_one_is_bitwise_flat_dlion_mavo() {
    let n = 4;
    let hp = StrategyHyper::default();
    let mavo = by_name("d-lion-mavo", &hp).unwrap();
    let local1 = by_name("d-lion-local(1)", &hp).unwrap();
    let a = run_sequential(&task(), mavo.as_ref(), n, &cfg(30, Topology::Star));
    let b = run_sequential(&task(), local1.as_ref(), n, &cfg(30, Topology::Star));
    assert_eq!(a.final_params, b.final_params, "H=1 must reproduce d-lion-mavo");
    for (x, y) in a.history.iter().zip(&b.history) {
        assert_eq!(x.uplink_bytes, y.uplink_bytes, "step {}", x.step);
        assert_eq!(x.downlink_bytes, y.downlink_bytes, "step {}", x.step);
    }
}

#[test]
fn hierarchical_sequential_and_threaded_agree_bit_exactly() {
    // Acceptance: ≥ 2 groups, params + per-step per-hop byte history.
    let n = 4;
    let topo = Topology::Hierarchical { group_size: 2 };
    let hp = StrategyHyper::default();
    let strat = by_name("d-lion-mavo", &hp).unwrap();
    let c = cfg(40, topo);
    let seq = run_sequential(&task(), strat.as_ref(), n, &c);
    let (thr, stats) = run_threaded(task_arc(), strat.as_ref(), n, &c);
    assert_eq!(seq.final_params, thr.final_params);
    assert_eq!(seq.history.len(), thr.history.len());
    for (s, t) in seq.history.iter().zip(&thr.history) {
        assert_eq!(s.uplink_bytes, t.uplink_bytes, "step {} uplink", s.step);
        assert_eq!(s.downlink_bytes, t.downlink_bytes, "step {} downlink", s.step);
        assert_eq!(s.agg_uplink_bytes, t.agg_uplink_bytes, "step {} agg uplink", s.step);
        assert_eq!(s.agg_downlink_bytes, t.agg_downlink_bytes, "step {} agg downlink", s.step);
    }
    // the transport counters cover every hop and match the history sums
    assert_eq!(stats.uplink(), seq.total_uplink());
    assert_eq!(stats.downlink(), seq.total_downlink());
    assert_eq!(stats.agg_uplink(), seq.total_agg_uplink());
    assert_eq!(stats.agg_downlink(), seq.total_agg_downlink());
    assert!(stats.agg_uplink() > 0, "two groups must move aggregator bytes");
}

#[test]
fn local_steps_sequential_and_threaded_agree_bit_exactly() {
    // Acceptance: d-lion-local(4), params + per-step byte history.
    let n = 4;
    let hp = StrategyHyper::default();
    let strat = by_name("d-lion-local(4)", &hp).unwrap();
    let c = cfg(40, Topology::Star); // 40 % 4 == 0: ends on a sync point
    let seq = run_sequential(&task(), strat.as_ref(), n, &c);
    let (thr, stats) = run_threaded(task_arc(), strat.as_ref(), n, &c);
    assert_eq!(seq.final_params, thr.final_params);
    for (s, t) in seq.history.iter().zip(&thr.history) {
        assert_eq!(s.uplink_bytes, t.uplink_bytes, "step {} uplink", s.step);
        assert_eq!(s.downlink_bytes, t.downlink_bytes, "step {} downlink", s.step);
        let sync = (s.step + 1) % 4 == 0;
        assert_eq!(s.uplink_bytes > 0, sync, "bytes only on sync steps (step {})", s.step);
    }
    // amortization on the wire: 10 sync rounds × n × (1 bit/param + tag)
    let expect_up = 10 * n as u64 * (1 + D.div_ceil(8) as u64);
    assert_eq!(stats.uplink(), expect_up);
}

#[test]
fn local_steps_compose_with_hierarchy() {
    // d-lion-local(4) over two groups: both drivers, bit-exact, and the
    // aggregator hop only moves bytes on sync steps.
    let n = 4;
    let topo = Topology::Hierarchical { group_size: 2 };
    let hp = StrategyHyper::default();
    let strat = by_name("d-lion-local(4)", &hp).unwrap();
    let c = cfg(24, topo);
    let seq = run_sequential(&task(), strat.as_ref(), n, &c);
    let (thr, stats) = run_threaded(task_arc(), strat.as_ref(), n, &c);
    assert_eq!(seq.final_params, thr.final_params);
    for (s, t) in seq.history.iter().zip(&thr.history) {
        assert_eq!(
            (s.uplink_bytes, s.downlink_bytes, s.agg_uplink_bytes, s.agg_downlink_bytes),
            (t.uplink_bytes, t.downlink_bytes, t.agg_uplink_bytes, t.agg_downlink_bytes),
            "step {}",
            s.step
        );
        if (s.step + 1) % 4 != 0 {
            assert_eq!(s.agg_uplink_bytes, 0, "local step {} moved aggregator bytes", s.step);
        }
    }
    assert_eq!(stats.agg_uplink(), seq.total_agg_uplink());
    // the local(4) trajectory under hier:2 equals local(4) under star
    // (vote partials are exact regardless of cadence)
    let star = run_sequential(&task(), strat.as_ref(), n, &cfg(24, Topology::Star));
    assert_eq!(star.final_params, seq.final_params);
}
