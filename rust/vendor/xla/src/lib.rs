//! Offline stub of the `xla` PJRT bindings.
//!
//! This container has no XLA/PJRT toolchain, so the coordinator links
//! against this API-compatible stub instead of the real bindings
//! (elie222/xla-rs lineage). Literal construction and marshalling work
//! (they are plain byte shuffling); anything that would need the PJRT
//! runtime — client construction, compilation, execution — returns a
//! structured [`Error`] that the `dlion` runtime layer surfaces as
//! "artifacts unavailable", which the tests and benches already gate on.
//! Swapping in the real crate is a one-line change in `rust/Cargo.toml`.

use std::path::Path;

/// Stub error: carries a message; formatted into `DlionError::Xla`.
#[derive(Debug, Clone)]
pub struct Error {
    pub message: String,
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error {
        message: format!(
            "{what}: XLA/PJRT runtime not available in this offline build \
             (stub crate rust/vendor/xla; install the real bindings to enable)"
        ),
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the dlion runtime marshals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S8,
    S32,
    S64,
    U8,
}

/// Native scalar types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    const SIZE: usize;
    fn write_le(&self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty, $ty:expr, $n:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;
            const SIZE: usize = $n;
            fn write_le(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn read_le(bytes: &[u8]) -> Self {
                <$t>::from_le_bytes(bytes.try_into().expect("literal byte width"))
            }
        }
    };
}

native!(f32, ElementType::F32, 4);
native!(f64, ElementType::F64, 8);
native!(i8, ElementType::S8, 1);
native!(i32, ElementType::S32, 4);
native!(i64, ElementType::S64, 8);
native!(u8, ElementType::U8, 1);

/// A host-side tensor literal (bytes + dims + dtype). Construction and
/// read-back work in the stub; only device execution is unavailable.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    /// 1-D literal from a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * T::SIZE);
        for v in data {
            v.write_le(&mut bytes);
        }
        Literal { ty: T::TY, dims: vec![data.len() as i64], bytes }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        let mut bytes = Vec::with_capacity(T::SIZE);
        v.write_le(&mut bytes);
        Literal { ty: T::TY, dims: vec![], bytes }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let new: i64 = dims.iter().product();
        let old: i64 = self.dims.iter().product();
        if new != old {
            return Err(Error {
                message: format!("reshape {:?} -> {dims:?}: element count mismatch", self.dims),
            });
        }
        Ok(Literal { ty: self.ty, dims: dims.to_vec(), bytes: self.bytes.clone() })
    }

    /// Build from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    /// Read back as a native vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error {
                message: format!("to_vec: literal is {:?}, requested {:?}", self.ty, T::TY),
            });
        }
        Ok(self.bytes.chunks_exact(T::SIZE).map(T::read_le).collect())
    }

    /// Copy raw elements into a preallocated buffer.
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        if dst.len() * T::SIZE != self.bytes.len() {
            return Err(Error {
                message: format!(
                    "copy_raw_to: literal has {} bytes, destination wants {}",
                    self.bytes.len(),
                    dst.len() * T::SIZE
                ),
            });
        }
        for (d, c) in dst.iter_mut().zip(self.bytes.chunks_exact(T::SIZE)) {
            *d = T::read_le(c);
        }
        Ok(())
    }

    /// Flatten a tuple literal — only produced by execution, which the
    /// stub cannot do.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module text (held verbatim; the stub cannot compile it).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| Error {
            message: format!("read {}: {e}", path.as_ref().display()),
        })?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper (stub).
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: () }
    }
}

/// PJRT client (stub: construction fails cleanly).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Loaded executable (stub: unreachable, execution always errors).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let v = vec![1.0f32, -2.5, 0.0];
        let lit = Literal::vec1(&v);
        assert_eq!(lit.to_vec::<f32>().unwrap(), v);
        let r = lit.reshape(&[3, 1]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), v);
        assert!(lit.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn literal_i8_from_untyped() {
        let bytes = [1u8, 255, 0];
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S8, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<i8>().unwrap(), vec![1, -1, 0]);
    }

    #[test]
    fn copy_raw_to_checks_width() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        let mut out = [0.0f32; 2];
        lit.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, [1.0, 2.0]);
        let mut bad = [0.0f32; 3];
        assert!(lit.copy_raw_to(&mut bad).is_err());
    }

    #[test]
    fn runtime_paths_fail_cleanly() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("not available"));
    }
}
